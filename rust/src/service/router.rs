//! The band-partition router: one MinHash, N slices x R replicas,
//! OR-reduced verdicts — the multi-host half of the serving tier
//! (`route` subcommand).
//!
//! A router fronts a fleet of dedup servers arranged as *replica sets*:
//! each set serves one contiguous band slice of the same index geometry
//! (`serve --slice-index I --slice-count N`; a single full
//! concurrent-engine server also works as the degenerate slice 0 of 1)
//! and may hold R identical copies of that slice — in the backend spec,
//! commas separate slices and pipes separate replicas
//! (`--backends "h1:7001|h2:7001,h1:7002|h2:7002"` is 2 slices x 2
//! replicas). For every `check`/`check_batch` the router MinHashes the
//! text *once*, fans the resulting band vectors to every live replica
//! of every slice with the band-level wire ops (`check_bands` /
//! `check_bands_batch`) — so backends never re-MinHash — and OR-reduces
//! the per-slice verdicts, which is exactly the single-index duplicate
//! rule (any band collides, §4.2). OR-reducing is also what makes
//! replication free of coordination: replicas of one slice hold the
//! same bits, so OR-ing across however many happen to answer can never
//! change a verdict. Batched requests additionally run the shared
//! intra-batch reconcile ([`crate::engine::reconcile_in_batch`]) at the
//! router, so batch verdicts stay byte-identical to a single
//! concurrent-engine server.
//!
//! ## Fleet validation and failure model
//!
//! At bind the router performs a stats handshake with every replica and
//! fails fast on a misconfigured fleet: every backend must accept
//! band-level ops (a classic text-only server is rejected here, not on
//! the first routed request), serve the router's band count *and* rows
//! per band (two perm counts can derive the same band count with
//! different rows — band count alone would silently miss every probe),
//! declare a slice count equal to the number of replica sets, agree
//! with its set peers on both the slice index and the `inserted`
//! counter (two diverged copies cannot both be probe sources; restart
//! the stale one with `serve --sync-from` so anti-entropy re-converges
//! it first), and the sets' slice indices must be a permutation of
//! `0..N` — together, by the [`crate::engine::slice_range`] tiling,
//! that proves the fleet covers every band exactly once.
//!
//! At serve time each client connection owns one dedicated connection
//! per live replica (established lazily, reused across requests —
//! requests are pipelined: written to every live replica before any
//! reply is read, so the whole fleet works concurrently without
//! router-side threads; each fan-out line is serialized once and
//! size-checked before anything is sent). Failures are scoped to the
//! replica that produced them: a replica that refuses a connection,
//! times out, or answers with an error is marked down — out of probe
//! rotation until `{"op":"revive"}` re-admits it — and its set fails
//! over to the surviving copies, so killing any single backend of a
//! replicated slice mid-stream degrades no verdict. Only when an entire
//! set is unreachable does the request fail, split by blast radius: a
//! pre-flight rejection (over-expanded batch, no replica of some slice
//! connectable) provably sent nothing and only costs an error reply,
//! while losing a set's last replica after the first byte went out is
//! **fail-fast** — the client receives an error naming the backend and
//! the connection closes, because a half-applied fan-out (some slices
//! inserted, others not) can no longer promise exact verdicts on that
//! stream.
//!
//! Every replica carries a dirty-epoch counter: each acknowledged
//! insert fan-out advances it, so a replica that missed traffic while
//! down lags the set maximum by exactly its missed inserts
//! (`router.replica.dirty_epoch`). `revive` marks a replica
//! probe-eligible again only after a fresh handshake shows geometry,
//! slice, and `inserted` parity with a healthy peer of its set — the
//! state a restarted replica reaches by bind-time anti-entropy
//! (`serve --sync-from`, a bit-OR
//! [`merge`](crate::engine::BandSliceIndex::merge_band_words) of the
//! peer's `pull_bands` stream).
//!
//! ## Tracing and health
//!
//! The router is where distributed traces are usually born: every
//! request opens a [`crate::obs::trace`] root span (adopting the
//! client's `trace` context when present), and a fan-out that will
//! record stamps that context onto the broadcast line so each backend
//! parents its own span under this one. As replies land, a `hop
//! <addr>` span per backend records the client-side latency *and* the
//! backend's self-reported span ID + duration, so wire time and server
//! time split per hop (`/debug/traces`, `{"op":"trace_dump"}`, and the
//! `--trace-slow-ms` log line all show the breakdown). On the metrics
//! endpoint, `/healthz` is pure liveness while `/readyz` tracks the
//! fleet per replica set: ready while every slice keeps at least one
//! healthy replica, so one dead copy of a replicated slice degrades
//! `router.replicas_healthy{slice=...}` without clearing readiness —
//! only a slice with no live replica left does that.

use super::client::DedupClient;
use super::proto::error_response;
use super::server::ServerStats;
use super::DEFAULT_MAX_LINE_BYTES;
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::engine::reconcile_in_batch;
use crate::json::{self, obj, Value};
use crate::methods::lshbloom::BandPreparer;
use crate::methods::{Prepared, Preparer};
use crate::minhash::LshParams;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Default for [`RouterOptions::connect_timeout`]: how long a backend
/// may take to accept a connection before the router treats it as
/// down. A partitioned host (packets silently dropped) would otherwise
/// hold a client thread for the OS connect default — minutes — instead
/// of failing fast.
pub const DEFAULT_BACKEND_CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Default for [`RouterOptions::read_timeout`]: how long the router
/// waits for one backend reply. Dedup ops are memory-speed (a capped
/// request line parses and probes in well under a second), so a stall
/// this long means a hung backend, and that replica must be marked
/// down (or, for a slice's last copy, the fail-fast contract — error
/// naming the backend, close the client stream — must fire) rather
/// than block forever (which would also wedge router shutdown on the
/// connection join).
pub const DEFAULT_BACKEND_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Listener-level router options.
#[derive(Clone, Debug)]
pub struct RouterOptions {
    /// Per-connection request-line cap in bytes
    /// ([`DEFAULT_MAX_LINE_BYTES`] unless overridden).
    pub max_line_bytes: usize,
    /// Backend connect timeout (`route --backend-connect-timeout`,
    /// default [`DEFAULT_BACKEND_CONNECT_TIMEOUT`]). Tune down for
    /// same-rack fleets that should fail over fast, up for WAN hops.
    pub connect_timeout: Duration,
    /// Backend reply timeout (`route --backend-read-timeout`, default
    /// [`DEFAULT_BACKEND_READ_TIMEOUT`]).
    pub read_timeout: Duration,
    /// `HOST:PORT` for the router's Prometheus metrics endpoint
    /// (`route --metrics-addr`); `None` disables it.
    pub metrics_addr: Option<String>,
}

impl Default for RouterOptions {
    fn default() -> Self {
        Self {
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            connect_timeout: DEFAULT_BACKEND_CONNECT_TIMEOUT,
            read_timeout: DEFAULT_BACKEND_READ_TIMEOUT,
            metrics_addr: None,
        }
    }
}

/// One backend endpoint: a single copy of one band slice.
struct Replica {
    addr: String,
    /// Probe eligibility. True from the bind handshake until any
    /// failure attributed to this replica (connect refused, send/recv
    /// error, read timeout, error reply); only `{"op":"revive"}` — a
    /// fresh handshake proving parity with a healthy set peer — sets it
    /// back. Requests simply skip unhealthy replicas, which is the
    /// failover: the set's surviving copies keep answering.
    healthy: AtomicBool,
    /// Count of insert operations this replica has *acknowledged*
    /// (check fan-outs weigh 1, check_bands_batch fan-outs weigh the
    /// batch length). A replica that was down, or whose ack was never
    /// read, lags the set maximum by exactly its possibly-missed
    /// inserts — the `router.replica.dirty_epoch` gauge — making missed
    /// traffic detectable even though the bit-OR merge that repairs it
    /// is idempotent either way.
    epoch: AtomicU64,
}

/// The replicas serving one band slice. Every member holds (a copy of)
/// the same filters, so probes may be answered by any live subset and
/// inserts must reach every live member.
struct ReplicaSet {
    /// The slice index this set serves, from the bind handshake (the
    /// spec's comma order need not match slice order).
    slice: usize,
    replicas: Vec<Replica>,
}

impl ReplicaSet {
    fn healthy_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.healthy.load(Ordering::SeqCst)).count()
    }

    fn max_epoch(&self) -> u64 {
        self.replicas.iter().map(|r| r.epoch.load(Ordering::SeqCst)).max().unwrap_or(0)
    }
}

/// Per-connection backend connections, `fleet[set][replica]` mirroring
/// `RouterShared::sets`. `None` until first use (or after the replica
/// failed / was marked down elsewhere); re-filled from the shared
/// health flags on each broadcast.
type Fleet = Vec<Vec<Option<DedupClient>>>;

struct RouterShared {
    preparer: BandPreparer,
    num_bands: usize,
    sets: Vec<ReplicaSet>,
    max_line_bytes: usize,
    connect_timeout: Duration,
    read_timeout: Duration,
    /// Tracing knobs (`--trace-sample`, `--trace-slow-ms`), per router
    /// instance so in-process fleets with different settings coexist.
    trace: crate::obs::TraceParams,
    /// Fleet readiness for `/readyz`: true while every replica set
    /// keeps at least one healthy member. One dead copy of a replicated
    /// slice degrades the `router.replicas_healthy` gauge but not
    /// readiness; a slice with no live replica clears it until the
    /// fleet recovers (`revive`, or a later fan-out succeeding).
    /// Liveness (`/healthz`) never follows it — a router with a sick
    /// backend is alive but not ready.
    ready: Arc<AtomicBool>,
    stats: ServerStats,
    shutdown: AtomicBool,
}

/// A failed fan-out, split by blast radius: `fatal` failures may have
/// partially applied (some slices mutated, others not), so the client
/// stream can no longer promise exact verdicts and must close; clean
/// failures provably sent nothing (pre-flight size check, no replica
/// of some slice connectable) and only need an error reply — the
/// client keeps its connection and can retry or split the batch.
struct Failure {
    msg: String,
    fatal: bool,
}

impl Failure {
    fn fatal(msg: String) -> Self {
        Self { msg, fatal: true }
    }

    fn clean(msg: String) -> Self {
        Self { msg, fatal: false }
    }
}

/// A running band-partition router.
pub struct DedupRouter {
    listener: TcpListener,
    shared: Arc<RouterShared>,
    /// Prometheus scrape endpoint (`--metrics-addr`); stops when the
    /// router is dropped at the end of `serve`.
    metrics: Option<crate::obs::MetricsHttp>,
}

fn invalid_input(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidInput, msg)
}

impl DedupRouter {
    /// Bind to `addr`, fronting `backends`: one element per band slice,
    /// each either a single dedup-server address or a `|`-separated
    /// replica group serving identical copies of that slice
    /// (`"h1:7001|h2:7001"`). `cfg` fixes the MinHash/band geometry —
    /// it must match the geometry every backend was started with, and
    /// the handshake verifies the observable half of that (band count,
    /// rows per band, slice layout, and within-set `inserted`
    /// agreement) before the listener opens.
    pub fn bind(
        addr: &str,
        cfg: &PipelineConfig,
        backends: Vec<String>,
        opts: &RouterOptions,
    ) -> std::io::Result<Self> {
        if backends.is_empty() {
            return Err(invalid_input("route: need at least one backend".to_string()));
        }
        let groups: Vec<Vec<String>> = backends
            .iter()
            .map(|spec| {
                spec.split('|')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .collect();
        if let Some(i) = groups.iter().position(|g: &Vec<String>| g.is_empty()) {
            return Err(invalid_input(format!(
                "route: backend spec '{}' names no replica addresses (write `addr` or \
                 `addr1|addr2`)",
                backends[i]
            )));
        }
        let preparer = BandPreparer::from_config(cfg);
        let num_bands = preparer.lsh.num_bands;
        let slices = validate_backend_layout(
            &groups,
            preparer.lsh,
            opts.connect_timeout,
            opts.read_timeout,
        )?;
        let sets: Vec<ReplicaSet> = groups
            .into_iter()
            .zip(slices)
            .map(|(addrs, slice)| ReplicaSet {
                slice,
                replicas: addrs
                    .into_iter()
                    .map(|addr| Replica {
                        addr,
                        healthy: AtomicBool::new(true),
                        epoch: AtomicU64::new(0),
                    })
                    .collect(),
            })
            .collect();
        // The handshake above just proved the whole fleet answers and
        // tiles the band space — that is the readiness criterion, so
        // the flag starts true here and only a fully-dead replica set
        // clears it.
        let ready = Arc::new(AtomicBool::new(true));
        let shared = Arc::new(RouterShared {
            preparer,
            num_bands,
            sets,
            max_line_bytes: opts.max_line_bytes,
            connect_timeout: opts.connect_timeout,
            read_timeout: opts.read_timeout,
            trace: crate::obs::TraceParams {
                sample: cfg.trace_sample,
                slow_ms: cfg.trace_slow_ms,
            },
            ready: Arc::clone(&ready),
            stats: ServerStats::default(),
            shutdown: AtomicBool::new(false),
        });
        crate::obs::init();
        // Publish the replication gauges at their bind-time values so a
        // scrape taken before any traffic already shows the fleet shape
        // (R healthy replicas per slice, zero epoch lag everywhere).
        let reg = crate::obs::global();
        for set in &shared.sets {
            reg.gauge(&format!("router.replicas_healthy{{slice=\"{}\"}}", set.slice))
                .set(set.replicas.len() as f64);
            for rep in &set.replicas {
                reg.gauge(&format!("router.replica.dirty_epoch{{backend=\"{}\"}}", rep.addr))
                    .set(0.0);
            }
        }
        // The router owns no filters, so scrapes need no refresh hook —
        // its registry entries (fan-out latency, backend errors,
        // replica health) are updated inline on the request path.
        // Readiness reads the fleet-health flag maintained there.
        let metrics = match &opts.metrics_addr {
            Some(maddr) => Some(crate::obs::MetricsHttp::bind(
                maddr,
                None,
                Some(Box::new(move || ready.load(Ordering::SeqCst))),
            )?),
            None => None,
        };
        let listener = TcpListener::bind(addr)?;
        Ok(Self { listener, shared, metrics })
    }

    /// The bound address (for ephemeral-port tests).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// The bound metrics-endpoint address, when `metrics_addr` was set
    /// (resolves port 0 to the ephemeral port actually bound).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.metrics.as_ref().map(|m| m.local_addr())
    }

    /// Number of backend endpoints this router fans out to (replicas
    /// summed across all slices).
    pub fn num_backends(&self) -> usize {
        self.shared.sets.iter().map(|s| s.replicas.len()).sum()
    }

    /// Serve until a client sends `{"op":"shutdown"}` — the same
    /// accept/poll loop as [`super::DedupServer::serve`]. Shutting the
    /// router down does *not* shut the backends down: they may be
    /// shared with other routers; stop them directly when the fleet
    /// retires.
    pub fn serve(self) -> std::io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            handles.retain(|h| !h.is_finished());
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(stream, shared)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Stats-handshake every replica of every set and fail fast unless the
/// fleet forms a complete, non-overlapping band partition of this
/// router's geometry (band count AND rows per band — two perm counts
/// can derive the same band count with different rows, which would
/// silently miss every probe) served by band-capable backends, with
/// every set internally agreeing on its slice and its `inserted`
/// counter (replicas that diverged while one was down must re-converge
/// via `serve --sync-from` before they may serve probes). Returns each
/// set's slice index, in spec order.
fn validate_backend_layout(
    sets: &[Vec<String>],
    lsh: LshParams,
    connect_timeout: Duration,
    read_timeout: Duration,
) -> std::io::Result<Vec<usize>> {
    let mut seen = vec![false; sets.len()];
    let mut slices = Vec::with_capacity(sets.len());
    for replicas in sets {
        let mut set_slice: Option<usize> = None;
        let mut set_inserted: Option<(&str, u64)> = None;
        let mut set_generations: Option<(&str, u64)> = None;
        for addr in replicas {
            let fail = |msg: String| invalid_input(format!("route: backend {addr}: {msg}"));
            let mut client = connect_backend(addr, connect_timeout, read_timeout)
                .map_err(|e| fail(format!("connect failed: {e}")))?;
            let stats = client.stats_json().map_err(|e| fail(e.to_string()))?;
            let get = |k: &str| stats.get(k).and_then(|v| v.as_usize());
            let (Some(bands), Some(rows), Some(index), Some(count)) = (
                get("num_bands"),
                get("rows_per_band"),
                get("slice_index"),
                get("slice_count"),
            ) else {
                return Err(fail(
                    "stats response lacks the band-layout fields (num_bands/rows_per_band/\
                     slice_index/slice_count) — not a band-aware dedup server?"
                        .to_string(),
                ));
            };
            if stats.get("band_ops").and_then(|v| v.as_bool()) != Some(true) {
                return Err(fail(
                    "serves text ops only (classic engine); router backends must accept \
                     band-level ops — start it with --engine concurrent"
                        .to_string(),
                ));
            }
            if bands != lsh.num_bands || rows != lsh.rows_per_band {
                return Err(fail(format!(
                    "serves {bands} bands x {rows} rows but the router's geometry derives \
                     {} x {} (threshold/perms/p-effective/expected-docs must match across \
                     the fleet)",
                    lsh.num_bands, lsh.rows_per_band
                )));
            }
            if count != sets.len() {
                return Err(fail(format!(
                    "declares slice count {count} but the router was given {} backend \
                     replica sets",
                    sets.len()
                )));
            }
            match set_slice {
                None => {
                    if index >= count || seen[index] {
                        return Err(fail(format!(
                            "slice index {index} is out of range or already claimed by \
                             another backend — the fleet must be a permutation of slices \
                             0..{count}"
                        )));
                    }
                    seen[index] = true;
                    set_slice = Some(index);
                }
                Some(s) if s != index => {
                    return Err(fail(format!(
                        "claims slice {index} but its replica group serves slice {s} — \
                         every replica behind one `|` group must serve the same slice"
                    )));
                }
                Some(_) => {}
            }
            // Replicas of one slice must be bit-equal copies; the
            // `inserted` counter is the cheap observable proxy the
            // handshake can check. Servers that predate the field are
            // admitted unchecked rather than rejected.
            if let Some(ins) = stats.get("inserted").and_then(|v| v.as_u64()) {
                match set_inserted {
                    None => set_inserted = Some((addr, ins)),
                    Some((peer, peer_ins)) if peer_ins != ins => {
                        return Err(fail(format!(
                            "reports {ins} inserted documents but its replica peer {peer} \
                             reports {peer_ins} — the copies diverged; restart the stale \
                             one with `serve --sync-from {peer}` so anti-entropy \
                             re-converges it before it serves probes"
                        )));
                    }
                    Some(_) => {}
                }
            }
            // Same contract for the generation layout: replicas of one
            // slice must agree on how many frozen+open generations they
            // hold, or a probe answered by the shallower copy could miss
            // a duplicate recorded in a generation it never grew.
            // Servers that predate the field are admitted unchecked
            // rather than rejected.
            if let Some(gens) = stats.get("generations").and_then(|v| v.as_u64()) {
                match set_generations {
                    None => set_generations = Some((addr, gens)),
                    Some((peer, peer_gens)) if peer_gens != gens => {
                        return Err(fail(format!(
                            "holds {gens} index generation(s) but its replica peer {peer} \
                             holds {peer_gens} — the copies diverged across a rotation; \
                             restart the stale one with `serve --sync-from {peer}` so \
                             anti-entropy grows and re-converges it before it serves probes"
                        )));
                    }
                    Some(_) => {}
                }
            }
        }
        // Every replica group was checked non-empty at bind, so the
        // first replica filled this in; the unwrap-free form keeps the
        // bind path panic-free.
        slices.push(set_slice.unwrap_or(0));
    }
    Ok(slices)
}

/// Open one timed-out backend connection (see [`RouterOptions`]).
fn connect_backend(
    addr: &str,
    connect_timeout: Duration,
    read_timeout: Duration,
) -> std::io::Result<DedupClient> {
    DedupClient::connect_with_timeouts(addr, connect_timeout, read_timeout)
}

/// Take one replica out of probe rotation after a failure attributed to
/// it — connect refused, send or receive error (including a read
/// timeout), or an error reply. The labeled counter is what a fleet
/// dashboard alerts on: a single backend's series climbing while the
/// others stay flat localizes the sick host, and
/// `router.replicas_healthy` dropping below R on one slice is the page.
/// Readiness is recomputed per replica set: the fleet stays ready while
/// every slice keeps at least one live copy, and only `{"op":"revive"}`
/// (a fresh parity handshake) puts this replica back.
fn mark_replica_down(shared: &RouterShared, set: &ReplicaSet, rep: &Replica) {
    let reg = crate::obs::global();
    reg.counter(&format!("router.backend.errors.total{{backend=\"{}\"}}", rep.addr)).inc();
    reg.counter("router.backend.errors.total").inc();
    rep.healthy.store(false, Ordering::SeqCst);
    reg.gauge(&format!("router.replicas_healthy{{slice=\"{}\"}}", set.slice))
        .set(set.healthy_count() as f64);
    update_readiness(shared);
}

/// Recompute `/readyz` from the shared health flags: ready iff every
/// replica set keeps at least one healthy member.
fn update_readiness(shared: &RouterShared) {
    let ready = shared.sets.iter().all(|set| set.healthy_count() > 0);
    shared.ready.store(ready, Ordering::SeqCst);
}

/// Refresh the per-replica `router.replica.dirty_epoch` gauges: each
/// replica's lag behind its set's maximum acknowledged-insert epoch —
/// an upper bound on the inserts it may have missed while down, and
/// the series an operator watches drain to zero after `--sync-from`
/// anti-entropy plus `revive`.
fn update_dirty_epochs(shared: &RouterShared) {
    let reg = crate::obs::global();
    for set in &shared.sets {
        let max = set.max_epoch();
        for rep in &set.replicas {
            let lag = max.saturating_sub(rep.epoch.load(Ordering::SeqCst));
            reg.gauge(&format!("router.replica.dirty_epoch{{backend=\"{}\"}}", rep.addr))
                .set(lag as f64);
        }
    }
}

/// The clean/fatal message for a replica set with no live member left.
/// Always names the word "backend" plus every address, so operators
/// (and the fail-fast contract) see which hosts to restart.
fn dead_set_msg(set: &ReplicaSet) -> String {
    let addrs: Vec<&str> = set.replicas.iter().map(|r| r.addr.as_str()).collect();
    format!(
        "slice {}: every backend replica is down ({}); restart the dead hosts (with \
         --sync-from for anti-entropy) and send {{\"op\":\"revive\"}} to re-admit them",
        set.slice,
        addrs.join(", ")
    )
}

fn handle_conn(stream: TcpStream, shared: Arc<RouterShared>) {
    // One dedicated connection per live replica, established at the
    // first op that needs the fleet and reused for every later request
    // on this client connection. The line loop itself is shared with
    // the dedup server (`proto::serve_connection`); the close flag
    // fires on the fail-fast path after a replica set empties out.
    let mut fleet: Fleet =
        shared.sets.iter().map(|s| s.replicas.iter().map(|_| None).collect()).collect();
    super::proto::serve_connection(stream, &shared.shutdown, shared.max_line_bytes, |line| {
        handle_request(line, &shared, &mut fleet)
    });
}

/// Handle one request line; the bool asks the connection loop to close
/// after replying (fail-fast after a replica set lost its last live
/// member mid-fan-out — a half-applied fan-out cannot keep serving
/// exact verdicts on this stream).
fn handle_request(line: &str, shared: &RouterShared, fleet: &mut Fleet) -> (Value, bool) {
    let reg = crate::obs::global();
    let inflight = reg.gauge("router.inflight_requests");
    inflight.add(1.0);
    let start = std::time::Instant::now();
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            inflight.add(-1.0);
            reg.counter("router.errors.total").inc();
            return (error_response(format!("bad request json: {e}")), false);
        }
    };
    let op = req.get("op").and_then(|v| v.as_str()).map(str::to_string);
    // The router is where a distributed trace is usually minted; a
    // traced client's `trace` field is adopted instead. The root span
    // covers MinHash + the whole fan-out, with `hop <addr>` children
    // recorded as backend replies land.
    let ctx = super::proto::trace_from_request(&req);
    let label = op.as_deref().unwrap_or("unknown");
    let root = match ctx {
        Some(c) => crate::obs::trace::adopt_root(c, label, shared.trace),
        None => crate::obs::trace::start_root(label, shared.trace),
    };
    let (mut resp, close) = dispatch_request(&req, shared, fleet);
    // Same contract as the server: only dedup ops feed the latency
    // histograms, so sample counts track requests routed, not scrapes.
    if let Some(op) = op.as_deref().filter(|&op| matches!(op, "check" | "query" | "check_batch")) {
        let elapsed = start.elapsed();
        reg.histogram("router.request.seconds").record_duration(elapsed);
        reg.histogram(&format!("router.request.seconds{{op=\"{op}\"}}"))
            .record_duration(elapsed);
        reg.counter("router.requests.total").inc();
    }
    if resp.get("error").is_some() {
        reg.counter("router.errors.total").inc();
        // Error traces always record, whatever the sampling verdict.
        crate::obs::trace::force_record();
    }
    if ctx.is_some() {
        // A traced client gets this router's span ID and self-measured
        // duration back, mirroring what backends report to the router.
        if let Some(local) = crate::obs::trace::current_context() {
            if let Value::Obj(map) = &mut resp {
                map.insert(
                    "trace".to_string(),
                    super::proto::trace_reply(local.span_id, start.elapsed().as_nanos() as u64),
                );
            }
        }
    }
    drop(root);
    inflight.add(-1.0);
    (resp, close)
}

fn dispatch_request(req: &Value, shared: &RouterShared, fleet: &mut Fleet) -> (Value, bool) {
    match req.get("op").and_then(|v| v.as_str()) {
        Some("check") | Some("query") => {
            let insert = req.get("op").and_then(|v| v.as_str()) == Some("check");
            let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
                return (error_response("missing 'text'"), false);
            };
            let bands = prepare_one(shared, text);
            match fan_check(shared, fleet, &bands, insert) {
                Ok(duplicate) if insert => {
                    let id = shared.stats.docs.fetch_add(1, Ordering::SeqCst);
                    if duplicate {
                        shared.stats.duplicates.fetch_add(1, Ordering::SeqCst);
                    }
                    let resp = obj(vec![
                        ("duplicate", Value::Bool(duplicate)),
                        ("id", Value::u64(id)),
                    ]);
                    (resp, false)
                }
                Ok(duplicate) => (obj(vec![("duplicate", Value::Bool(duplicate))]), false),
                Err(f) => (error_response(f.msg), f.fatal),
            }
        }
        Some("check_batch") => {
            let Some(texts_json) = req.get("texts").and_then(|v| v.as_arr()) else {
                return (error_response("missing 'texts' array"), false);
            };
            let mut texts = Vec::with_capacity(texts_json.len());
            for (i, t) in texts_json.iter().enumerate() {
                let Some(s) = t.as_str() else {
                    return (error_response(format!("texts[{i}] is not a string")), false);
                };
                texts.push(s);
            }
            let bands_batch = prepare_batch(shared, &texts);
            match fan_check_batch(shared, fleet, &bands_batch) {
                Ok(verdicts) => {
                    let n = texts.len() as u64;
                    let first_id = shared.stats.docs.fetch_add(n, Ordering::SeqCst);
                    let dups = verdicts.iter().filter(|&&d| d).count() as u64;
                    shared.stats.duplicates.fetch_add(dups, Ordering::SeqCst);
                    let resp = obj(vec![
                        (
                            "duplicates",
                            Value::Arr(verdicts.into_iter().map(Value::Bool).collect()),
                        ),
                        (
                            "ids",
                            Value::Arr((0..n).map(|i| Value::u64(first_id + i)).collect()),
                        ),
                    ]);
                    (resp, false)
                }
                Err(f) => (error_response(f.msg), f.fatal),
            }
        }
        Some("stats") => match fan_stats(shared, fleet) {
            Ok(disk_bytes) => {
                let replicas: usize = shared.sets.iter().map(|s| s.replicas.len()).sum();
                let resp = obj(vec![
                    ("docs", Value::u64(shared.stats.docs.load(Ordering::SeqCst))),
                    (
                        "duplicates",
                        Value::u64(shared.stats.duplicates.load(Ordering::SeqCst)),
                    ),
                    ("disk_bytes", Value::u64(disk_bytes)),
                    ("num_bands", Value::u64(shared.num_bands as u64)),
                    ("slices", Value::u64(shared.sets.len() as u64)),
                    ("backends", Value::u64(replicas as u64)),
                    ("uptime_seconds", Value::num(crate::obs::uptime_seconds())),
                    ("version", Value::str(env!("CARGO_PKG_VERSION"))),
                ]);
                (resp, false)
            }
            Err(f) => (error_response(f.msg), f.fatal),
        },
        Some("revive") => (revive_fleet(shared), false),
        Some("metrics") => (crate::obs::global().to_json(), false),
        Some("trace_dump") => (super::proto::trace_dump_response(req), false),
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            (obj(vec![("ok", Value::Bool(true))]), false)
        }
        Some(other) => {
            let msg = format!(
                "unknown op '{other}' (the router serves check/query/check_batch/\
                 stats/revive/metrics/trace_dump/shutdown; band-level ops go directly to \
                 slice backends)"
            );
            (error_response(msg), false)
        }
        None => (error_response("missing 'op'"), false),
    }
}

fn prepare_one(shared: &RouterShared, text: &str) -> Vec<u64> {
    let doc = Doc { id: 0, text: text.to_string() };
    let mut prepared = shared.preparer.prepare_batch(std::slice::from_ref(&doc));
    let Prepared::Bands(bands) = prepared.remove(0) else { unreachable!() };
    bands
}

fn prepare_batch(shared: &RouterShared, texts: &[&str]) -> Vec<Vec<u64>> {
    let docs: Vec<Doc> = texts
        .iter()
        .enumerate()
        .map(|(i, t)| Doc { id: i as u64, text: (*t).to_string() })
        .collect();
    shared
        .preparer
        .prepare_batch(&docs)
        .into_iter()
        .map(|prep| {
            let Prepared::Bands(bands) = prep else { unreachable!() };
            bands
        })
        .collect()
}

/// Write `req` to every live replica of every set, then read every
/// reply — pipelined, so the whole fleet processes concurrently over
/// dedicated connections. The request is serialized once and
/// size-checked against the router's own line cap *before anything is
/// sent*: band encoding expands short documents (~21 bytes per band
/// hash), so a client batch under the cap can re-encode past it — that
/// must be a clean pre-flight error, never a torn half-broadcast
/// against backends that enforce their own caps.
///
/// Failures are attributed to the replica that produced them and mark
/// it down; the broadcast continues on the set's surviving members and
/// only fails when some set ends the round with zero successful
/// replies — cleanly if that is discovered before any byte went out
/// (connect phase), fatally afterwards. `insert_weight` is the number
/// of insert operations `req` carries (0 for probes/stats); each
/// acknowledging replica's epoch advances by it, which is what makes a
/// down replica's missed inserts observable as `dirty_epoch` lag.
///
/// Returns, per set in spec order, the non-empty list of
/// `(replica_index, reply)` pairs that succeeded.
fn broadcast(
    shared: &RouterShared,
    fleet: &mut Fleet,
    req: &Value,
    insert_weight: u64,
) -> Result<Vec<Vec<(usize, Value)>>, Failure> {
    // The span covers the whole fan-out (serialize + send-all +
    // read-all); per-replica latency is recorded below as each reply
    // lands, so a slow slice shows up in its own labeled series.
    let _fan = crate::obs::span("router.fan_out");
    let reg = crate::obs::global();
    // A trace that will (or may yet) record pays the wire bytes for
    // propagation: the broadcast line carries this root's context so
    // every backend parents its span under it. Unsampled traffic
    // serializes the caller's request untouched.
    let traced = crate::obs::trace::should_propagate();
    let line = match crate::obs::trace::current_context().filter(|_| traced) {
        Some(ctx) => {
            let mut stamped = req.clone();
            super::proto::attach_trace(&mut stamped, &ctx);
            stamped.to_json() + "\n"
        }
        None => req.to_json() + "\n",
    };
    if line.len() > shared.max_line_bytes {
        // Pre-flight, nothing sent: a clean reply, connection kept.
        return Err(Failure::clean(format!(
            "fan-out request is {} bytes of band-encoded JSON, over the {}-byte line \
             cap (band vectors expand short documents); split the batch, or raise \
             --max-line-bytes on the router and every backend",
            line.len(),
            shared.max_line_bytes
        )));
    }
    // Connect phase — still clean: no request bytes have gone anywhere,
    // so a slice with no connectable replica only costs an error reply.
    for (set, conns) in shared.sets.iter().zip(fleet.iter_mut()) {
        let mut last_err: Option<String> = None;
        for (rep, conn) in set.replicas.iter().zip(conns.iter_mut()) {
            if !rep.healthy.load(Ordering::SeqCst) {
                // Marked down (possibly by another connection); drop
                // any cached connection so a later revive reconnects
                // fresh instead of reusing a dead socket.
                *conn = None;
                continue;
            }
            if conn.is_none() {
                match connect_backend(&rep.addr, shared.connect_timeout, shared.read_timeout) {
                    Ok(c) => *conn = Some(c),
                    Err(e) => {
                        last_err = Some(format!("backend {}: {e}", rep.addr));
                        mark_replica_down(shared, set, rep);
                    }
                }
            }
        }
        if !conns.iter().any(|c| c.is_some()) {
            return Err(Failure::clean(last_err.unwrap_or_else(|| dead_set_msg(set))));
        }
    }
    // Send phase. From the first send onward a failure may be
    // half-applied, so an emptied set is now fatal.
    let start = std::time::Instant::now();
    for (set, conns) in shared.sets.iter().zip(fleet.iter_mut()) {
        let mut last_err: Option<String> = None;
        for (rep, conn) in set.replicas.iter().zip(conns.iter_mut()) {
            let Some(c) = conn.as_mut() else { continue };
            if let Err(e) = c.send_raw(&line) {
                last_err = Some(format!("backend {}: {e}", rep.addr));
                mark_replica_down(shared, set, rep);
                *conn = None;
            }
        }
        if !conns.iter().any(|c| c.is_some()) {
            return Err(Failure::fatal(last_err.unwrap_or_else(|| dead_set_msg(set))));
        }
    }
    // Receive phase: collect each set's surviving replies.
    let mut replies: Vec<Vec<(usize, Value)>> = Vec::with_capacity(shared.sets.len());
    for (set, conns) in shared.sets.iter().zip(fleet.iter_mut()) {
        let mut set_replies: Vec<(usize, Value)> = Vec::new();
        let mut last_err: Option<String> = None;
        for (ri, (rep, conn)) in set.replicas.iter().zip(conns.iter_mut()).enumerate() {
            let Some(c) = conn.as_mut() else { continue };
            let resp = match c.recv() {
                Ok(resp) => resp,
                Err(e) => {
                    last_err = Some(format!("backend {}: {e}", rep.addr));
                    mark_replica_down(shared, set, rep);
                    *conn = None;
                    continue;
                }
            };
            // Requests are pipelined, so each replica's series measures
            // send-all → its reply read: an upper bound on that
            // backend's service time, and the per-slice signal worth
            // graphing.
            reg.histogram(&format!("router.backend.seconds{{backend=\"{}\"}}", rep.addr))
                .record_duration(start.elapsed());
            if traced {
                // One hop span per backend, reusing the backend's own
                // span ID (two views of one RPC) with its self-reported
                // duration alongside the client-side wall time measured
                // here.
                let (remote_span, remote_ns) =
                    super::proto::trace_timing_from_reply(&resp).unwrap_or((0, 0));
                crate::obs::trace::record_hop(
                    &format!("hop {}", rep.addr),
                    remote_span,
                    start.elapsed(),
                    remote_ns,
                );
            }
            if let Some(err) = resp.get("error").and_then(|v| v.as_str()) {
                last_err = Some(format!("backend {}: {err}", rep.addr));
                mark_replica_down(shared, set, rep);
                *conn = None;
                continue;
            }
            if insert_weight > 0 {
                rep.epoch.fetch_add(insert_weight, Ordering::SeqCst);
            }
            set_replies.push((ri, resp));
        }
        if set_replies.is_empty() {
            return Err(Failure::fatal(last_err.unwrap_or_else(|| dead_set_msg(set))));
        }
        replies.push(set_replies);
    }
    if insert_weight > 0 {
        update_dirty_epochs(shared);
    }
    // Every set answered: as far as this router can observe the fleet
    // serves full coverage again, so readiness recovers here (computed
    // from the per-replica flags, never blanket-set).
    update_readiness(shared);
    Ok(replies)
}

/// Fan one band vector to every slice and OR-reduce the verdicts
/// across every replica that answered (replicas hold the same bits, so
/// the OR is redundancy, not a semantic change).
fn fan_check(
    shared: &RouterShared,
    fleet: &mut Fleet,
    bands: &[u64],
    insert: bool,
) -> Result<bool, Failure> {
    let req = obj(vec![
        ("op", Value::str("check_bands")),
        ("bands", super::proto::bands_to_json(bands)),
        ("insert", Value::Bool(insert)),
    ]);
    let replies = broadcast(shared, fleet, &req, u64::from(insert))?;
    let mut duplicate = false;
    for (set, set_replies) in shared.sets.iter().zip(&replies) {
        for (ri, resp) in set_replies {
            let Some(d) = resp.get("duplicate").and_then(|v| v.as_bool()) else {
                return Err(Failure::fatal(format!(
                    "backend {}: malformed check_bands response",
                    set.replicas[*ri].addr
                )));
            };
            duplicate |= d;
        }
    }
    Ok(duplicate)
}

/// Fan a band-vector batch to every slice, OR-reduce the pre-batch
/// verdicts across sets and surviving replicas, then apply the shared
/// intra-batch reconcile — the final verdicts are byte-identical to a
/// single concurrent-engine server processing the same batch.
fn fan_check_batch(
    shared: &RouterShared,
    fleet: &mut Fleet,
    bands_batch: &[Vec<u64>],
) -> Result<Vec<bool>, Failure> {
    let docs: Vec<Value> = bands_batch.iter().map(|b| super::proto::bands_to_json(b)).collect();
    let req = obj(vec![
        ("op", Value::str("check_bands_batch")),
        ("bands_batch", Value::Arr(docs)),
    ]);
    let replies = broadcast(shared, fleet, &req, bands_batch.len() as u64)?;
    let mut pre = vec![false; bands_batch.len()];
    for (set, set_replies) in shared.sets.iter().zip(&replies) {
        for (ri, resp) in set_replies {
            let addr = &set.replicas[*ri].addr;
            let Some(arr) = resp.get("pre_duplicates").and_then(|v| v.as_arr()) else {
                return Err(Failure::fatal(format!(
                    "backend {addr}: malformed check_bands_batch response"
                )));
            };
            if arr.len() != bands_batch.len() {
                return Err(Failure::fatal(format!(
                    "backend {addr}: sent {} band vectors, got {} verdicts",
                    bands_batch.len(),
                    arr.len()
                )));
            }
            for (p, v) in pre.iter_mut().zip(arr) {
                let Some(d) = v.as_bool() else {
                    return Err(Failure::fatal(format!(
                        "backend {addr}: malformed check_bands_batch response"
                    )));
                };
                *p |= d;
            }
        }
    }
    Ok(reconcile_in_batch(bands_batch, &pre))
}

/// Aggregate the fleet's persisted footprint for the router's stats
/// reply: sum of backend `disk_bytes`, counting each slice once (its
/// first surviving reply) — replicas are copies, not extra coverage.
fn fan_stats(shared: &RouterShared, fleet: &mut Fleet) -> Result<u64, Failure> {
    let req = obj(vec![("op", Value::str("stats"))]);
    let replies = broadcast(shared, fleet, &req, 0)?;
    let mut disk_bytes = 0u64;
    for (set, set_replies) in shared.sets.iter().zip(&replies) {
        // Broadcast never returns an empty per-set list, but spelling
        // that out keeps this path panic-free.
        let Some((ri, resp)) = set_replies.first() else {
            return Err(Failure::fatal(dead_set_msg(set)));
        };
        let Some(b) = resp.get("disk_bytes").and_then(|v| v.as_u64()) else {
            return Err(Failure::fatal(format!(
                "backend {}: malformed stats response",
                set.replicas[*ri].addr
            )));
        };
        disk_bytes += b;
    }
    Ok(disk_bytes)
}

/// `{"op":"revive"}`: try to re-admit every downed replica. Each one
/// gets the bind-time handshake again — geometry, slice identity, and
/// `inserted` parity with a healthy peer of its set (the state a
/// restarted replica reaches via `serve --sync-from` anti-entropy). A
/// replica that passes is marked probe-eligible with its epoch advanced
/// to the set maximum (its lag is repaired, not forgiven); one that
/// fails stays out of rotation with the reason reported, never touching
/// the live fleet. Replies `{"revived": [addr...], "failed": [{"addr",
/// "error"}...]}`.
fn revive_fleet(shared: &RouterShared) -> Value {
    let reg = crate::obs::global();
    let mut revived: Vec<Value> = Vec::new();
    let mut failed: Vec<Value> = Vec::new();
    for set in &shared.sets {
        if set.healthy_count() == set.replicas.len() {
            continue;
        }
        let (peer_inserted, peer_generations) = healthy_peer_state(shared, set);
        let max_epoch = set.max_epoch();
        for rep in &set.replicas {
            if rep.healthy.load(Ordering::SeqCst) {
                continue;
            }
            match revive_one(shared, set, rep, peer_inserted, peer_generations) {
                Ok(()) => {
                    rep.epoch.store(max_epoch, Ordering::SeqCst);
                    rep.healthy.store(true, Ordering::SeqCst);
                    revived.push(Value::str(&rep.addr));
                }
                Err(msg) => {
                    failed.push(obj(vec![
                        ("addr", Value::str(&rep.addr)),
                        ("error", Value::str(&msg)),
                    ]));
                }
            }
        }
        reg.gauge(&format!("router.replicas_healthy{{slice=\"{}\"}}", set.slice))
            .set(set.healthy_count() as f64);
    }
    update_dirty_epochs(shared);
    update_readiness(shared);
    obj(vec![
        ("revived", Value::Arr(revived)),
        ("failed", Value::Arr(failed)),
    ])
}

/// The `inserted` counter and generation count of the first healthy,
/// answering replica of `set` — the convergence targets a revival
/// candidate must match. With no healthy peer left (double fault) there
/// is nothing to compare against and the candidate is re-admitted on
/// geometry alone: it holds the only surviving copy.
fn healthy_peer_state(shared: &RouterShared, set: &ReplicaSet) -> (Option<u64>, Option<u64>) {
    for rep in &set.replicas {
        if !rep.healthy.load(Ordering::SeqCst) {
            continue;
        }
        let Ok(mut client) =
            connect_backend(&rep.addr, shared.connect_timeout, shared.read_timeout)
        else {
            continue;
        };
        let Ok(stats) = client.stats_json() else { continue };
        if let Some(ins) = stats.get("inserted").and_then(|v| v.as_u64()) {
            return (Some(ins), stats.get("generations").and_then(|v| v.as_u64()));
        }
    }
    (None, None)
}

/// Re-run the bind-time handshake against one downed replica; `Ok`
/// means it may rejoin probe rotation.
fn revive_one(
    shared: &RouterShared,
    set: &ReplicaSet,
    rep: &Replica,
    peer_inserted: Option<u64>,
    peer_generations: Option<u64>,
) -> Result<(), String> {
    let lsh = shared.preparer.lsh;
    let mut client = connect_backend(&rep.addr, shared.connect_timeout, shared.read_timeout)
        .map_err(|e| format!("connect failed: {e}"))?;
    let stats = client.stats_json().map_err(|e| e.to_string())?;
    let get = |k: &str| stats.get(k).and_then(|v| v.as_usize());
    let (Some(bands), Some(rows), Some(index), Some(count)) = (
        get("num_bands"),
        get("rows_per_band"),
        get("slice_index"),
        get("slice_count"),
    ) else {
        return Err(
            "stats response lacks the band-layout fields — not a band-aware dedup server?"
                .to_string(),
        );
    };
    if stats.get("band_ops").and_then(|v| v.as_bool()) != Some(true) {
        return Err("serves text ops only (classic engine); router backends must accept \
                    band-level ops"
            .to_string());
    }
    if bands != lsh.num_bands || rows != lsh.rows_per_band {
        return Err(format!(
            "serves {bands} bands x {rows} rows but the router's geometry derives {} x {}",
            lsh.num_bands, lsh.rows_per_band
        ));
    }
    if index != set.slice || count != shared.sets.len() {
        return Err(format!(
            "serves slice {index} of {count} but this replica set is slice {} of {}",
            set.slice,
            shared.sets.len()
        ));
    }
    if let (Some(peer), Some(mine)) =
        (peer_inserted, stats.get("inserted").and_then(|v| v.as_u64()))
    {
        if peer != mine {
            return Err(format!(
                "inserted counter is {mine} but its healthy peer holds {peer} — restart it \
                 with `serve --sync-from` so anti-entropy converges the copies first"
            ));
        }
    }
    if let (Some(peer), Some(mine)) =
        (peer_generations, stats.get("generations").and_then(|v| v.as_u64()))
    {
        if peer != mine {
            return Err(format!(
                "holds {mine} index generation(s) but its healthy peer holds {peer} — \
                 restart it with `serve --sync-from` so anti-entropy grows and converges \
                 the copies first"
            ));
        }
    }
    Ok(())
}
