//! The band-partition router: one MinHash, N backends, OR-reduced
//! verdicts — the multi-host half of the serving tier (`route`
//! subcommand).
//!
//! A router fronts `N` dedup servers, each serving one contiguous band
//! slice of the same index geometry (`serve --slice-index I
//! --slice-count N`; a single full concurrent-engine server also works
//! as the degenerate slice 0 of 1). For every `check`/`check_batch` the router MinHashes
//! the text *once*, fans the resulting band vectors across all backends
//! with the band-level wire ops (`check_bands` /
//! `check_bands_batch`) — so backends never re-MinHash — and OR-reduces
//! the per-slice verdicts, which is exactly the single-index duplicate
//! rule (any band collides, §4.2). Batched requests additionally run
//! the shared intra-batch reconcile
//! ([`crate::engine::reconcile_in_batch`]) at the router, so batch
//! verdicts stay byte-identical to a single concurrent-engine server.
//!
//! ## Fleet validation and failure model
//!
//! At bind the router performs a stats handshake with every backend and
//! fails fast on a misconfigured fleet: every backend must accept
//! band-level ops (a classic text-only server is rejected here, not on
//! the first routed request), serve the router's band count *and* rows
//! per band (two perm counts can derive the same band count with
//! different rows — band count alone would silently miss every probe),
//! declare a slice count equal to the number of backends, and the slice
//! indices must be a permutation of `0..N` — together, by the
//! [`crate::engine::slice_range`] tiling, that proves the fleet covers
//! every band exactly once.
//!
//! At serve time each client connection owns one dedicated connection
//! per backend (established lazily, reused across requests — requests
//! are pipelined: written to all N backends before any reply is read,
//! so the slices work concurrently without router-side threads; each
//! fan-out line is serialized once and size-checked before anything is
//! sent). Failures split by blast radius: a pre-flight rejection
//! (over-expanded batch, backend connect refused) provably sent nothing
//! and only costs an error reply, while any failure after the first
//! byte went out is **fail-fast** — the client receives an error naming
//! the backend and the connection closes, because a half-applied
//! fan-out (some slices inserted, others not) can no longer promise
//! exact verdicts on that stream. Re-connecting gets a fresh fan-out
//! against whatever fleet is alive.
//!
//! ## Tracing and health
//!
//! The router is where distributed traces are usually born: every
//! request opens a [`crate::obs::trace`] root span (adopting the
//! client's `trace` context when present), and a fan-out that will
//! record stamps that context onto the broadcast line so each backend
//! parents its own span under this one. As replies land, a `hop
//! <addr>` span per backend records the client-side latency *and* the
//! backend's self-reported span ID + duration, so wire time and server
//! time split per hop (`/debug/traces`, `{"op":"trace_dump"}`, and the
//! `--trace-slow-ms` log line all show the breakdown). On the metrics
//! endpoint, `/healthz` is pure liveness while `/readyz` tracks the
//! fleet: ready once the bind-time handshake passes, not-ready again
//! after any backend failure until a full fan-out succeeds — a router
//! with a dead backend keeps running (liveness) but reports itself
//! unfit for new traffic (readiness).

use super::client::DedupClient;
use super::proto::error_response;
use super::server::ServerStats;
use super::DEFAULT_MAX_LINE_BYTES;
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::engine::reconcile_in_batch;
use crate::json::{self, obj, Value};
use crate::methods::lshbloom::BandPreparer;
use crate::methods::{Prepared, Preparer};
use crate::minhash::LshParams;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Default for [`RouterOptions::connect_timeout`]: how long a backend
/// may take to accept a connection before the router treats it as
/// down. A partitioned host (packets silently dropped) would otherwise
/// hold a client thread for the OS connect default — minutes — instead
/// of failing fast.
pub const DEFAULT_BACKEND_CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Default for [`RouterOptions::read_timeout`]: how long the router
/// waits for one backend reply. Dedup ops are memory-speed (a capped
/// request line parses and probes in well under a second), so a stall
/// this long means a hung backend, and the fail-fast contract — error
/// naming the backend, close the client stream — must fire rather than
/// block forever (which would also wedge router shutdown on the
/// connection join).
pub const DEFAULT_BACKEND_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Listener-level router options.
#[derive(Clone, Debug)]
pub struct RouterOptions {
    /// Per-connection request-line cap in bytes
    /// ([`DEFAULT_MAX_LINE_BYTES`] unless overridden).
    pub max_line_bytes: usize,
    /// Backend connect timeout (`route --backend-connect-timeout`,
    /// default [`DEFAULT_BACKEND_CONNECT_TIMEOUT`]). Tune down for
    /// same-rack fleets that should fail over fast, up for WAN hops.
    pub connect_timeout: Duration,
    /// Backend reply timeout (`route --backend-read-timeout`, default
    /// [`DEFAULT_BACKEND_READ_TIMEOUT`]).
    pub read_timeout: Duration,
    /// `HOST:PORT` for the router's Prometheus metrics endpoint
    /// (`route --metrics-addr`); `None` disables it.
    pub metrics_addr: Option<String>,
}

impl Default for RouterOptions {
    fn default() -> Self {
        Self {
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            connect_timeout: DEFAULT_BACKEND_CONNECT_TIMEOUT,
            read_timeout: DEFAULT_BACKEND_READ_TIMEOUT,
            metrics_addr: None,
        }
    }
}

struct RouterShared {
    preparer: BandPreparer,
    num_bands: usize,
    backends: Vec<String>,
    max_line_bytes: usize,
    connect_timeout: Duration,
    read_timeout: Duration,
    /// Tracing knobs (`--trace-sample`, `--trace-slow-ms`), per router
    /// instance so in-process fleets with different settings coexist.
    trace: crate::obs::TraceParams,
    /// Fleet readiness for `/readyz`: true after the bind-time
    /// handshake, false after any backend failure, true again once a
    /// full fan-out succeeds. Liveness (`/healthz`) never follows it —
    /// a router with a sick backend is alive but not ready.
    ready: Arc<AtomicBool>,
    stats: ServerStats,
    shutdown: AtomicBool,
}

/// A failed fan-out, split by blast radius: `fatal` failures may have
/// partially applied (some backends mutated, others not), so the client
/// stream can no longer promise exact verdicts and must close; clean
/// failures provably sent nothing (pre-flight size check, connect
/// refused) and only need an error reply — the client keeps its
/// connection and can retry or split the batch.
struct Failure {
    msg: String,
    fatal: bool,
}

impl Failure {
    fn fatal(msg: String) -> Self {
        Self { msg, fatal: true }
    }

    fn clean(msg: String) -> Self {
        Self { msg, fatal: false }
    }
}

/// A running band-partition router.
pub struct DedupRouter {
    listener: TcpListener,
    shared: Arc<RouterShared>,
    /// Prometheus scrape endpoint (`--metrics-addr`); stops when the
    /// router is dropped at the end of `serve`.
    metrics: Option<crate::obs::MetricsHttp>,
}

fn invalid_input(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidInput, msg)
}

impl DedupRouter {
    /// Bind to `addr`, fronting `backends` (dedup-server addresses, one
    /// per band slice). `cfg` fixes the MinHash/band geometry — it must
    /// match the geometry every backend was started with, and the
    /// handshake verifies the observable half of that (band count and
    /// slice layout) before the listener opens.
    pub fn bind(
        addr: &str,
        cfg: &PipelineConfig,
        backends: Vec<String>,
        opts: &RouterOptions,
    ) -> std::io::Result<Self> {
        if backends.is_empty() {
            return Err(invalid_input("route: need at least one backend".to_string()));
        }
        let preparer = BandPreparer::from_config(cfg);
        let num_bands = preparer.lsh.num_bands;
        validate_backend_layout(&backends, preparer.lsh, opts.connect_timeout, opts.read_timeout)?;
        // The handshake above just proved the whole fleet answers and
        // tiles the band space — that is the readiness criterion, so
        // the flag starts true here and only backend failures clear it.
        let ready = Arc::new(AtomicBool::new(true));
        let shared = Arc::new(RouterShared {
            preparer,
            num_bands,
            backends,
            max_line_bytes: opts.max_line_bytes,
            connect_timeout: opts.connect_timeout,
            read_timeout: opts.read_timeout,
            trace: crate::obs::TraceParams {
                sample: cfg.trace_sample,
                slow_ms: cfg.trace_slow_ms,
            },
            ready: Arc::clone(&ready),
            stats: ServerStats::default(),
            shutdown: AtomicBool::new(false),
        });
        crate::obs::init();
        // The router owns no filters, so scrapes need no refresh hook —
        // its registry entries (fan-out latency, backend errors) are
        // updated inline on the request path. Readiness reads the
        // fleet-health flag maintained by the broadcast path.
        let metrics = match &opts.metrics_addr {
            Some(maddr) => Some(crate::obs::MetricsHttp::bind(
                maddr,
                None,
                Some(Box::new(move || ready.load(Ordering::SeqCst))),
            )?),
            None => None,
        };
        let listener = TcpListener::bind(addr)?;
        Ok(Self { listener, shared, metrics })
    }

    /// The bound address (for ephemeral-port tests).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// The bound metrics-endpoint address, when `metrics_addr` was set
    /// (resolves port 0 to the ephemeral port actually bound).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.metrics.as_ref().map(|m| m.local_addr())
    }

    /// Number of backends this router fans out to.
    pub fn num_backends(&self) -> usize {
        self.shared.backends.len()
    }

    /// Serve until a client sends `{"op":"shutdown"}` — the same
    /// accept/poll loop as [`super::DedupServer::serve`]. Shutting the
    /// router down does *not* shut the backends down: they may be
    /// shared with other routers; stop them directly when the fleet
    /// retires.
    pub fn serve(self) -> std::io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            handles.retain(|h| !h.is_finished());
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(stream, shared)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Stats-handshake every backend and fail fast unless the fleet forms a
/// complete, non-overlapping band partition of this router's geometry
/// (band count AND rows per band — two perm counts can derive the same
/// band count with different rows, which would silently miss every
/// probe) served by band-capable backends.
fn validate_backend_layout(
    backends: &[String],
    lsh: LshParams,
    connect_timeout: Duration,
    read_timeout: Duration,
) -> std::io::Result<()> {
    let mut seen = vec![false; backends.len()];
    for addr in backends {
        let fail = |msg: String| invalid_input(format!("route: backend {addr}: {msg}"));
        let mut client = connect_backend(addr, connect_timeout, read_timeout)
            .map_err(|e| fail(format!("connect failed: {e}")))?;
        let stats = client.stats_json().map_err(|e| fail(e.to_string()))?;
        let get = |k: &str| stats.get(k).and_then(|v| v.as_usize());
        let (Some(bands), Some(rows), Some(index), Some(count)) = (
            get("num_bands"),
            get("rows_per_band"),
            get("slice_index"),
            get("slice_count"),
        ) else {
            return Err(fail(
                "stats response lacks the band-layout fields (num_bands/rows_per_band/\
                 slice_index/slice_count) — not a band-aware dedup server?"
                    .to_string(),
            ));
        };
        if stats.get("band_ops").and_then(|v| v.as_bool()) != Some(true) {
            return Err(fail(
                "serves text ops only (classic engine); router backends must accept \
                 band-level ops — start it with --engine concurrent"
                    .to_string(),
            ));
        }
        if bands != lsh.num_bands || rows != lsh.rows_per_band {
            return Err(fail(format!(
                "serves {bands} bands x {rows} rows but the router's geometry derives \
                 {} x {} (threshold/perms/p-effective/expected-docs must match across \
                 the fleet)",
                lsh.num_bands, lsh.rows_per_band
            )));
        }
        if count != backends.len() {
            return Err(fail(format!(
                "declares slice count {count} but the router was given {} backends",
                backends.len()
            )));
        }
        if index >= count || seen[index] {
            return Err(fail(format!(
                "slice index {index} is out of range or already claimed by another \
                 backend — the fleet must be a permutation of slices 0..{count}"
            )));
        }
        seen[index] = true;
    }
    Ok(())
}

/// Open one timed-out backend connection (see [`RouterOptions`]).
fn connect_backend(
    addr: &str,
    connect_timeout: Duration,
    read_timeout: Duration,
) -> std::io::Result<DedupClient> {
    DedupClient::connect_with_timeouts(addr, connect_timeout, read_timeout)
}

/// Count one failed interaction with `addr` — connect refused, send or
/// receive error (including a read timeout), or an error reply. The
/// labeled counter is what a fleet dashboard alerts on: a single
/// backend's series climbing while the others stay flat localizes the
/// sick host. Any backend failure also clears `/readyz` (a partial
/// fleet cannot serve exact verdicts) until a full fan-out succeeds.
fn count_backend_error(shared: &RouterShared, addr: &str) {
    let reg = crate::obs::global();
    reg.counter(&format!("router.backend.errors.total{{backend=\"{addr}\"}}")).inc();
    reg.counter("router.backend.errors.total").inc();
    shared.ready.store(false, Ordering::SeqCst);
}

fn handle_conn(stream: TcpStream, shared: Arc<RouterShared>) {
    // One dedicated connection per backend, established at the first op
    // that needs the fleet and reused for every later request on this
    // client connection. The line loop itself is shared with the dedup
    // server (`proto::serve_connection`); the close flag fires on the
    // fail-fast path after a backend error.
    let mut fleet: Option<Vec<DedupClient>> = None;
    super::proto::serve_connection(stream, &shared.shutdown, shared.max_line_bytes, |line| {
        handle_request(line, &shared, &mut fleet)
    });
}

/// Handle one request line; the bool asks the connection loop to close
/// after replying (fail-fast after a backend error — a half-applied
/// fan-out cannot keep serving exact verdicts on this stream).
fn handle_request(
    line: &str,
    shared: &RouterShared,
    fleet: &mut Option<Vec<DedupClient>>,
) -> (Value, bool) {
    let reg = crate::obs::global();
    let inflight = reg.gauge("router.inflight_requests");
    inflight.add(1.0);
    let start = std::time::Instant::now();
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            inflight.add(-1.0);
            reg.counter("router.errors.total").inc();
            return (error_response(format!("bad request json: {e}")), false);
        }
    };
    let op = req.get("op").and_then(|v| v.as_str()).map(str::to_string);
    // The router is where a distributed trace is usually minted; a
    // traced client's `trace` field is adopted instead. The root span
    // covers MinHash + the whole fan-out, with `hop <addr>` children
    // recorded as backend replies land.
    let ctx = super::proto::trace_from_request(&req);
    let label = op.as_deref().unwrap_or("unknown");
    let root = match ctx {
        Some(c) => crate::obs::trace::adopt_root(c, label, shared.trace),
        None => crate::obs::trace::start_root(label, shared.trace),
    };
    let (mut resp, close) = dispatch_request(&req, shared, fleet);
    // Same contract as the server: only dedup ops feed the latency
    // histograms, so sample counts track requests routed, not scrapes.
    if let Some(op) = op.as_deref().filter(|&op| matches!(op, "check" | "query" | "check_batch")) {
        let elapsed = start.elapsed();
        reg.histogram("router.request.seconds").record_duration(elapsed);
        reg.histogram(&format!("router.request.seconds{{op=\"{op}\"}}"))
            .record_duration(elapsed);
        reg.counter("router.requests.total").inc();
    }
    if resp.get("error").is_some() {
        reg.counter("router.errors.total").inc();
        // Error traces always record, whatever the sampling verdict.
        crate::obs::trace::force_record();
    }
    if ctx.is_some() {
        // A traced client gets this router's span ID and self-measured
        // duration back, mirroring what backends report to the router.
        if let Some(local) = crate::obs::trace::current_context() {
            if let Value::Obj(map) = &mut resp {
                map.insert(
                    "trace".to_string(),
                    super::proto::trace_reply(local.span_id, start.elapsed().as_nanos() as u64),
                );
            }
        }
    }
    drop(root);
    inflight.add(-1.0);
    (resp, close)
}

fn dispatch_request(
    req: &Value,
    shared: &RouterShared,
    fleet: &mut Option<Vec<DedupClient>>,
) -> (Value, bool) {
    match req.get("op").and_then(|v| v.as_str()) {
        Some("check") | Some("query") => {
            let insert = req.get("op").and_then(|v| v.as_str()) == Some("check");
            let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
                return (error_response("missing 'text'"), false);
            };
            let bands = prepare_one(shared, text);
            match fan_check(shared, fleet, &bands, insert) {
                Ok(duplicate) if insert => {
                    let id = shared.stats.docs.fetch_add(1, Ordering::SeqCst);
                    if duplicate {
                        shared.stats.duplicates.fetch_add(1, Ordering::SeqCst);
                    }
                    let resp = obj(vec![
                        ("duplicate", Value::Bool(duplicate)),
                        ("id", Value::u64(id)),
                    ]);
                    (resp, false)
                }
                Ok(duplicate) => (obj(vec![("duplicate", Value::Bool(duplicate))]), false),
                Err(f) => (error_response(f.msg), f.fatal),
            }
        }
        Some("check_batch") => {
            let Some(texts_json) = req.get("texts").and_then(|v| v.as_arr()) else {
                return (error_response("missing 'texts' array"), false);
            };
            let mut texts = Vec::with_capacity(texts_json.len());
            for (i, t) in texts_json.iter().enumerate() {
                let Some(s) = t.as_str() else {
                    return (error_response(format!("texts[{i}] is not a string")), false);
                };
                texts.push(s);
            }
            let bands_batch = prepare_batch(shared, &texts);
            match fan_check_batch(shared, fleet, &bands_batch) {
                Ok(verdicts) => {
                    let n = texts.len() as u64;
                    let first_id = shared.stats.docs.fetch_add(n, Ordering::SeqCst);
                    let dups = verdicts.iter().filter(|&&d| d).count() as u64;
                    shared.stats.duplicates.fetch_add(dups, Ordering::SeqCst);
                    let resp = obj(vec![
                        (
                            "duplicates",
                            Value::Arr(verdicts.into_iter().map(Value::Bool).collect()),
                        ),
                        (
                            "ids",
                            Value::Arr((0..n).map(|i| Value::u64(first_id + i)).collect()),
                        ),
                    ]);
                    (resp, false)
                }
                Err(f) => (error_response(f.msg), f.fatal),
            }
        }
        Some("stats") => match fan_stats(shared, fleet) {
            Ok(disk_bytes) => {
                let resp = obj(vec![
                    ("docs", Value::u64(shared.stats.docs.load(Ordering::SeqCst))),
                    (
                        "duplicates",
                        Value::u64(shared.stats.duplicates.load(Ordering::SeqCst)),
                    ),
                    ("disk_bytes", Value::u64(disk_bytes)),
                    ("num_bands", Value::u64(shared.num_bands as u64)),
                    ("backends", Value::u64(shared.backends.len() as u64)),
                    ("uptime_seconds", Value::num(crate::obs::uptime_seconds())),
                    ("version", Value::str(env!("CARGO_PKG_VERSION"))),
                ]);
                (resp, false)
            }
            Err(f) => (error_response(f.msg), f.fatal),
        },
        Some("metrics") => (crate::obs::global().to_json(), false),
        Some("trace_dump") => (super::proto::trace_dump_response(req), false),
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            (obj(vec![("ok", Value::Bool(true))]), false)
        }
        Some(other) => {
            let msg = format!(
                "unknown op '{other}' (the router serves check/query/check_batch/\
                 stats/metrics/trace_dump/shutdown; band-level ops go directly to \
                 slice backends)"
            );
            (error_response(msg), false)
        }
        None => (error_response("missing 'op'"), false),
    }
}

fn prepare_one(shared: &RouterShared, text: &str) -> Vec<u64> {
    let doc = Doc { id: 0, text: text.to_string() };
    let mut prepared = shared.preparer.prepare_batch(std::slice::from_ref(&doc));
    let Prepared::Bands(bands) = prepared.remove(0) else { unreachable!() };
    bands
}

fn prepare_batch(shared: &RouterShared, texts: &[&str]) -> Vec<Vec<u64>> {
    let docs: Vec<Doc> = texts
        .iter()
        .enumerate()
        .map(|(i, t)| Doc { id: i as u64, text: (*t).to_string() })
        .collect();
    shared
        .preparer
        .prepare_batch(&docs)
        .into_iter()
        .map(|prep| {
            let Prepared::Bands(bands) = prep else { unreachable!() };
            bands
        })
        .collect()
}

/// Connect the per-connection backend fleet on first use.
fn ensure_fleet<'a>(
    shared: &RouterShared,
    fleet: &'a mut Option<Vec<DedupClient>>,
) -> Result<&'a mut Vec<DedupClient>, String> {
    if fleet.is_none() {
        let mut conns = Vec::with_capacity(shared.backends.len());
        for addr in &shared.backends {
            let conn = connect_backend(addr, shared.connect_timeout, shared.read_timeout)
                .map_err(|e| {
                    count_backend_error(shared, addr);
                    format!("backend {addr}: {e}")
                })?;
            conns.push(conn);
        }
        *fleet = Some(conns);
    }
    // Filled directly above when it was None; expressing that through
    // ok_or keeps this connection-handler path panic-free.
    fleet.as_mut().ok_or_else(|| "router fleet unavailable after connect".to_string())
}

/// Write `req` to every backend, then read every reply — pipelined, so
/// all N backends process concurrently over their dedicated
/// connections. The request is serialized once for the whole fleet and
/// size-checked against the router's own line cap *before anything is
/// sent*: band encoding expands short documents (~21 bytes per band
/// hash), so a client batch under the cap can re-encode past it — that
/// must be a clean pre-flight error, never a torn half-broadcast
/// against backends that enforce their own caps. Any I/O failure or
/// error reply is attributed to the backend address that produced it.
fn broadcast(
    shared: &RouterShared,
    fleet: &mut Option<Vec<DedupClient>>,
    req: &Value,
) -> Result<Vec<Value>, Failure> {
    // The span covers the whole fan-out (serialize + send-all +
    // read-all); per-backend latency is recorded below as each reply
    // lands, so a slow slice shows up in its own labeled series.
    let _fan = crate::obs::span("router.fan_out");
    let reg = crate::obs::global();
    // A trace that will (or may yet) record pays the wire bytes for
    // propagation: the broadcast line carries this root's context so
    // every backend parents its span under it. Unsampled traffic
    // serializes the caller's request untouched.
    let traced = crate::obs::trace::should_propagate();
    let line = match crate::obs::trace::current_context().filter(|_| traced) {
        Some(ctx) => {
            let mut stamped = req.clone();
            super::proto::attach_trace(&mut stamped, &ctx);
            stamped.to_json() + "\n"
        }
        None => req.to_json() + "\n",
    };
    if line.len() > shared.max_line_bytes {
        // Pre-flight, nothing sent: a clean reply, connection kept.
        return Err(Failure::clean(format!(
            "fan-out request is {} bytes of band-encoded JSON, over the {}-byte line \
             cap (band vectors expand short documents); split the batch, or raise \
             --max-line-bytes on the router and every backend",
            line.len(),
            shared.max_line_bytes
        )));
    }
    // Connect failures are clean too — the fleet is only installed once
    // every backend connected, so no request bytes went anywhere.
    let conns = ensure_fleet(shared, fleet).map_err(Failure::clean)?;
    let start = std::time::Instant::now();
    for (conn, addr) in conns.iter_mut().zip(&shared.backends) {
        // From the first send onward a failure may be half-applied.
        conn.send_raw(&line).map_err(|e| {
            count_backend_error(shared, addr);
            Failure::fatal(format!("backend {addr}: {e}"))
        })?;
    }
    let mut replies = Vec::with_capacity(conns.len());
    for (conn, addr) in conns.iter_mut().zip(&shared.backends) {
        let resp = conn.recv().map_err(|e| {
            count_backend_error(shared, addr);
            Failure::fatal(format!("backend {addr}: {e}"))
        })?;
        // Requests are pipelined, so each backend's series measures
        // send-all → its reply read: an upper bound on that backend's
        // service time, and the per-slice signal worth graphing.
        reg.histogram(&format!("router.backend.seconds{{backend=\"{addr}\"}}"))
            .record_duration(start.elapsed());
        if traced {
            // One hop span per backend, reusing the backend's own span
            // ID (two views of one RPC) with its self-reported duration
            // alongside the client-side wall time measured here.
            let (remote_span, remote_ns) =
                super::proto::trace_timing_from_reply(&resp).unwrap_or((0, 0));
            crate::obs::trace::record_hop(
                &format!("hop {addr}"),
                remote_span,
                start.elapsed(),
                remote_ns,
            );
        }
        if let Some(err) = resp.get("error").and_then(|v| v.as_str()) {
            count_backend_error(shared, addr);
            return Err(Failure::fatal(format!("backend {addr}: {err}")));
        }
        replies.push(resp);
    }
    // Every backend answered cleanly: the fleet is healthy again as far
    // as this router can observe, so readiness recovers here.
    shared.ready.store(true, Ordering::SeqCst);
    Ok(replies)
}

/// Fan one band vector to every slice and OR-reduce the verdicts.
fn fan_check(
    shared: &RouterShared,
    fleet: &mut Option<Vec<DedupClient>>,
    bands: &[u64],
    insert: bool,
) -> Result<bool, Failure> {
    let req = obj(vec![
        ("op", Value::str("check_bands")),
        ("bands", super::proto::bands_to_json(bands)),
        ("insert", Value::Bool(insert)),
    ]);
    let replies = broadcast(shared, fleet, &req)?;
    let mut duplicate = false;
    for (resp, addr) in replies.iter().zip(&shared.backends) {
        let Some(d) = resp.get("duplicate").and_then(|v| v.as_bool()) else {
            return Err(Failure::fatal(format!(
                "backend {addr}: malformed check_bands response"
            )));
        };
        duplicate |= d;
    }
    Ok(duplicate)
}

/// Fan a band-vector batch to every slice, OR-reduce the pre-batch
/// verdicts, then apply the shared intra-batch reconcile — the final
/// verdicts are byte-identical to a single concurrent-engine server
/// processing the same batch.
fn fan_check_batch(
    shared: &RouterShared,
    fleet: &mut Option<Vec<DedupClient>>,
    bands_batch: &[Vec<u64>],
) -> Result<Vec<bool>, Failure> {
    let docs: Vec<Value> = bands_batch.iter().map(|b| super::proto::bands_to_json(b)).collect();
    let req = obj(vec![
        ("op", Value::str("check_bands_batch")),
        ("bands_batch", Value::Arr(docs)),
    ]);
    let replies = broadcast(shared, fleet, &req)?;
    let mut pre = vec![false; bands_batch.len()];
    for (resp, addr) in replies.iter().zip(&shared.backends) {
        let Some(arr) = resp.get("pre_duplicates").and_then(|v| v.as_arr()) else {
            return Err(Failure::fatal(format!(
                "backend {addr}: malformed check_bands_batch response"
            )));
        };
        if arr.len() != bands_batch.len() {
            return Err(Failure::fatal(format!(
                "backend {addr}: sent {} band vectors, got {} verdicts",
                bands_batch.len(),
                arr.len()
            )));
        }
        for (p, v) in pre.iter_mut().zip(arr) {
            let Some(d) = v.as_bool() else {
                return Err(Failure::fatal(format!(
                    "backend {addr}: malformed check_bands_batch response"
                )));
            };
            *p |= d;
        }
    }
    Ok(reconcile_in_batch(bands_batch, &pre))
}

/// Aggregate the fleet's persisted footprint (sum of backend
/// `disk_bytes`) for the router's stats reply.
fn fan_stats(
    shared: &RouterShared,
    fleet: &mut Option<Vec<DedupClient>>,
) -> Result<u64, Failure> {
    let req = obj(vec![("op", Value::str("stats"))]);
    let replies = broadcast(shared, fleet, &req)?;
    let mut disk_bytes = 0u64;
    for (resp, addr) in replies.iter().zip(&shared.backends) {
        let Some(b) = resp.get("disk_bytes").and_then(|v| v.as_u64()) else {
            return Err(Failure::fatal(format!("backend {addr}: malformed stats response")));
        };
        disk_bytes += b;
    }
    Ok(disk_bytes)
}
