//! Network deduplication service.
//!
//! Exposes the streaming SAMQ operation (§2.1) over a TCP line
//! protocol so upstream ingestion workers (scrapers, parser fleets) can
//! deduplicate against one shared index without linking the library —
//! the deployment shape the paper's introduction motivates (continuous
//! CommonCrawl-style drops feeding one corpus state).
//!
//! Protocol (JSON per line, newline-terminated):
//!
//! ```text
//! -> {"op": "check",  "text": "..."}           query + insert
//! <- {"duplicate": false, "id": 17}
//! -> {"op": "query",  "text": "..."}           query only (no insert)
//! <- {"duplicate": true}
//! -> {"op": "stats"}
//! <- {"docs": 17, "duplicates": 3, "disk_bytes": 1048576}
//! -> {"op": "shutdown"}
//! <- {"ok": true}
//! ```
//!
//! Concurrency model depends on [`crate::config::EngineMode`]. In
//! classic mode connection handlers parallelize MinHashing (the dominant
//! cost) and serialize index access behind one mutex, preserving the
//! §4.4.2 sequential-insert requirement. In concurrent mode
//! (`--engine concurrent`) the lock-free [`crate::engine`] serves both
//! MinHash and index work on connection threads with no serialization —
//! throughput scales with client count, at the cost of the engine
//! module's documented same-instant-twin caveat. Stats requests are
//! lock-free in both modes.

mod client;
mod server;

pub use client::DedupClient;
pub use server::{DedupServer, ServerStats};
