//! Network deduplication service.
//!
//! Exposes the streaming SAMQ operation (§2.1) over a TCP line
//! protocol so upstream ingestion workers (scrapers, parser fleets) can
//! deduplicate against one shared index without linking the library —
//! the deployment shape the paper's introduction motivates (continuous
//! CommonCrawl-style drops feeding one corpus state).
//!
//! Protocol (JSON per line, newline-terminated; request lines capped at
//! [`DEFAULT_MAX_LINE_BYTES`], configurable):
//!
//! ```text
//! -> {"op": "check",  "text": "..."}           query + insert
//! <- {"duplicate": false, "id": 17}
//! -> {"op": "query",  "text": "..."}           query only (no insert)
//! <- {"duplicate": true}
//! -> {"op": "check_batch", "texts": ["...", "..."]}
//! <- {"duplicates": [false, true], "ids": [18, 19]}
//! -> {"op": "check_bands", "bands": [b0, ..., b_{b-1}], "insert": true}
//! <- {"duplicate": false, "id": 20}            pre-MinHashed (router path)
//! -> {"op": "check_bands_batch", "bands_batch": [[...], [...]]}
//! <- {"pre_duplicates": [false, false]}        caller reconciles in-batch
//! -> {"op": "stats"}
//! <- {"docs": 21, "duplicates": 3, "disk_bytes": 1048576,
//!     "num_bands": 9, "slice_index": 0, "slice_count": 1, ...}
//! -> {"op": "shutdown"}
//! <- {"ok": true}
//! ```
//!
//! Concurrency model depends on the backend. In classic mode connection
//! handlers parallelize MinHashing (the dominant cost) and serialize
//! index access behind one mutex, preserving the §4.4.2
//! sequential-insert requirement. In concurrent mode (`--engine
//! concurrent`) the lock-free [`crate::engine`] serves both MinHash and
//! index work on connection threads with no serialization — throughput
//! scales with client count, at the cost of the engine module's
//! documented same-instant-twin caveat. Stats requests are lock-free in
//! every mode.
//!
//! ## The band-partitioned serving tier
//!
//! The LSHBloom index partitions cleanly along the band axis (the
//! duplicate rule is an OR across bands), and the serving tier exploits
//! that at two scales:
//!
//! * **In-process** — `serve --serve-shards N` runs N band-slice
//!   engines behind one listener ([`crate::engine::BandShardedEngine`]):
//!   one MinHash per request, parallel slice probes, OR-reduced
//!   verdicts identical to a single engine.
//! * **Multi-host** — `N` slice servers (`serve --slice-index I
//!   --slice-count N`, each holding `1/N` of the filter memory) behind
//!   a [`DedupRouter`] (`route` subcommand): the router MinHashes once,
//!   fans the band-level ops across the fleet over reused per-backend
//!   connections, OR-reduces remote verdicts, and fails fast — naming
//!   the backend — the moment one drops.
//!
//! See `docs/ARCHITECTURE.md` (serving-tier dataflow) and
//! `docs/OPERATIONS.md` (router deployment + backend-failure runbook).

mod client;
mod proto;
mod router;
mod server;

pub use client::DedupClient;
pub use proto::DEFAULT_MAX_LINE_BYTES;
pub use router::{DedupRouter, RouterOptions};
pub use server::{DedupServer, ServeOptions, ServerStats};
