//! Minimal blocking client for the dedup service.

use crate::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// A connected dedup-service client.
pub struct DedupClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl DedupClient {
    /// Connect to a [`super::DedupServer`].
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { writer: stream, reader })
    }

    fn round_trip(&mut self, req: Value) -> std::io::Result<Value> {
        self.writer.write_all((req.to_json() + "\n").as_bytes())?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad response: {e}"))
        })
    }

    /// Query + insert: is `text` a duplicate of anything seen so far?
    pub fn check(&mut self, text: &str) -> std::io::Result<bool> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("check")),
            ("text", Value::str(text)),
        ]))?;
        resp.get("duplicate")
            .and_then(|v| v.as_bool())
            .ok_or_else(|| err_from(&resp))
    }

    /// Query + insert a whole batch in one round trip
    /// (`{"op":"check_batch"}`): one syscall + one JSON parse per batch
    /// instead of per document, and the server runs the batch through
    /// the engine's batched fast path (which also reconciles twins
    /// *inside* the batch). Returns one verdict per text, in order.
    pub fn check_batch(&mut self, texts: &[&str]) -> std::io::Result<Vec<bool>> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("check_batch")),
            (
                "texts",
                Value::Arr(texts.iter().map(|t| Value::str(*t)).collect()),
            ),
        ]))?;
        let Some(arr) = resp.get("duplicates").and_then(|v| v.as_arr()) else {
            return Err(err_from(&resp));
        };
        if arr.len() != texts.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("check_batch: sent {} texts, got {} verdicts", texts.len(), arr.len()),
            ));
        }
        arr.iter()
            .map(|v| v.as_bool().ok_or_else(|| err_from(&resp)))
            .collect()
    }

    /// Query only (no state change).
    pub fn query(&mut self, text: &str) -> std::io::Result<bool> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("query")),
            ("text", Value::str(text)),
        ]))?;
        resp.get("duplicate")
            .and_then(|v| v.as_bool())
            .ok_or_else(|| err_from(&resp))
    }

    /// Server counters: (docs, duplicates, disk_bytes).
    pub fn stats(&mut self) -> std::io::Result<(u64, u64, u64)> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("stats"))]))?;
        let get = |k: &str| resp.get(k).and_then(|v| v.as_u64());
        match (get("docs"), get("duplicates"), get("disk_bytes")) {
            (Some(d), Some(dup), Some(b)) => Ok((d, dup, b)),
            _ => Err(err_from(&resp)),
        }
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown(&mut self) -> std::io::Result<()> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("shutdown"))]))?;
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(())
        } else {
            Err(err_from(&resp))
        }
    }
}

fn err_from(resp: &Value) -> std::io::Error {
    let msg = resp
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap_or("malformed response")
        .to_string();
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}
