//! Minimal blocking client for the dedup service.

use super::proto::bands_to_json;
use crate::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// A connected dedup-service client.
pub struct DedupClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl DedupClient {
    /// Connect to a [`super::DedupServer`] (or a [`super::DedupRouter`],
    /// which speaks the same text-op protocol).
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { writer: stream, reader })
    }

    /// [`Self::connect`] with explicit connect and read timeouts — the
    /// router's backend-facing constructor. A backend host that
    /// network-partitions (no FIN/RST, packets dropped) must surface as
    /// a timely I/O error so the fail-fast path can name it, not hold a
    /// connection thread for the OS default. A read timeout mid-reply
    /// desynchronizes the line framing, so treat any timeout as fatal
    /// for the connection (the router closes its whole fan-out).
    pub(crate) fn connect_with_timeouts(
        addr: &str,
        connect: std::time::Duration,
        read: std::time::Duration,
    ) -> std::io::Result<Self> {
        use std::net::ToSocketAddrs;
        let resolved = addr.to_socket_addrs()?.next().ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("address '{addr}' resolved to nothing"),
            )
        })?;
        let stream = TcpStream::connect_timeout(&resolved, connect)?;
        stream.set_read_timeout(Some(read))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { writer: stream, reader })
    }

    /// Write one request line without waiting for the response — the
    /// pipelining half the router uses to fan a request across N
    /// backends before reading any reply (all backends work
    /// concurrently, one connection each, no threads).
    pub(crate) fn send(&mut self, req: &Value) -> std::io::Result<()> {
        self.send_raw(&(req.to_json() + "\n"))
    }

    /// [`Self::send`] over a pre-serialized line (newline included) —
    /// lets the router serialize a large fan-out request once instead
    /// of once per backend.
    pub(crate) fn send_raw(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()
    }

    /// Read one response line. A clean EOF here means the server closed
    /// the connection — reported as [`std::io::ErrorKind::UnexpectedEof`]
    /// with that exact diagnosis, never disguised as a JSON parse error:
    /// the router's fail-fast path and human operators both need the
    /// real cause.
    pub(crate) fn recv(&mut self) -> std::io::Result<Value> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed connection",
            ));
        }
        json::parse(&line).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad response: {e}"))
        })
    }

    fn round_trip(&mut self, req: Value) -> std::io::Result<Value> {
        self.send(&req)?;
        self.recv()
    }

    /// Query + insert: is `text` a duplicate of anything seen so far?
    pub fn check(&mut self, text: &str) -> std::io::Result<bool> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("check")),
            ("text", Value::str(text)),
        ]))?;
        resp.get("duplicate")
            .and_then(|v| v.as_bool())
            .ok_or_else(|| err_from(&resp))
    }

    /// Query + insert a whole batch in one round trip
    /// (`{"op":"check_batch"}`): one syscall + one JSON parse per batch
    /// instead of per document, and the server runs the batch through
    /// the engine's batched fast path (which also reconciles twins
    /// *inside* the batch). Returns one verdict per text, in order.
    pub fn check_batch(&mut self, texts: &[&str]) -> std::io::Result<Vec<bool>> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("check_batch")),
            (
                "texts",
                Value::Arr(texts.iter().map(|t| Value::str(*t)).collect()),
            ),
        ]))?;
        let Some(arr) = resp.get("duplicates").and_then(|v| v.as_arr()) else {
            return Err(err_from(&resp));
        };
        if arr.len() != texts.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("check_batch: sent {} texts, got {} verdicts", texts.len(), arr.len()),
            ));
        }
        arr.iter()
            .map(|v| v.as_bool().ok_or_else(|| err_from(&resp)))
            .collect()
    }

    /// Query only (no state change).
    pub fn query(&mut self, text: &str) -> std::io::Result<bool> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("query")),
            ("text", Value::str(text)),
        ]))?;
        resp.get("duplicate")
            .and_then(|v| v.as_bool())
            .ok_or_else(|| err_from(&resp))
    }

    /// Band-level query + insert (`{"op":"check_bands"}`): send a
    /// pre-computed band-hash vector instead of text, so the server
    /// never re-MinHashes. Against a slice server the verdict covers
    /// only the bands that slice owns — OR it across the fleet (what
    /// [`super::DedupRouter`] does) for the full-index verdict.
    pub fn check_bands(&mut self, band_hashes: &[u64]) -> std::io::Result<bool> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("check_bands")),
            ("bands", bands_to_json(band_hashes)),
        ]))?;
        resp.get("duplicate")
            .and_then(|v| v.as_bool())
            .ok_or_else(|| err_from(&resp))
    }

    /// Band-level batch (`{"op":"check_bands_batch"}`): probe + insert
    /// the whole batch, returning the server's *pre-batch* verdicts.
    /// Final verdicts need the intra-batch reconcile
    /// ([`crate::engine::reconcile_in_batch`]) applied by the caller —
    /// the router does this after OR-reducing across its backends.
    pub fn check_bands_batch(&mut self, batch: &[Vec<u64>]) -> std::io::Result<Vec<bool>> {
        let docs: Vec<Value> = batch.iter().map(|b| bands_to_json(b)).collect();
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("check_bands_batch")),
            ("bands_batch", Value::Arr(docs)),
        ]))?;
        let Some(arr) = resp.get("pre_duplicates").and_then(|v| v.as_arr()) else {
            return Err(err_from(&resp));
        };
        if arr.len() != batch.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "check_bands_batch: sent {} band vectors, got {} verdicts",
                    batch.len(),
                    arr.len()
                ),
            ));
        }
        arr.iter()
            .map(|v| v.as_bool().ok_or_else(|| err_from(&resp)))
            .collect()
    }

    /// Server counters: (docs, duplicates, disk_bytes).
    pub fn stats(&mut self) -> std::io::Result<(u64, u64, u64)> {
        let resp = self.stats_json()?;
        let get = |k: &str| resp.get(k).and_then(|v| v.as_u64());
        match (get("docs"), get("duplicates"), get("disk_bytes")) {
            (Some(d), Some(dup), Some(b)) => Ok((d, dup, b)),
            _ => Err(err_from(&resp)),
        }
    }

    /// The raw `{"op":"stats"}` response — the full document, including
    /// the band-layout fields (`num_bands`, `slice_index`,
    /// `slice_count`) the router's startup handshake validates.
    pub fn stats_json(&mut self) -> std::io::Result<Value> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("stats"))]))?;
        if resp.get("error").is_some() {
            return Err(err_from(&resp));
        }
        Ok(resp)
    }

    /// The raw `{"op":"metrics"}` response — the full observability
    /// registry (counters, gauges, histogram summaries) as JSON; the
    /// wire twin of the `--metrics-addr` HTTP endpoint.
    pub fn metrics_json(&mut self) -> std::io::Result<Value> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("metrics"))]))?;
        if resp.get("error").is_some() {
            return Err(err_from(&resp));
        }
        Ok(resp)
    }

    /// The raw `{"op":"trace_dump"}` response — recent distributed
    /// traces from the peer's span ring (`{"traces": [...]}`, newest
    /// first); the wire twin of the `/debug/traces` HTTP route.
    pub fn trace_dump(&mut self) -> std::io::Result<Value> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("trace_dump"))]))?;
        if resp.get("error").is_some() {
            return Err(err_from(&resp));
        }
        Ok(resp)
    }

    /// Pull one band's filter words from a band-capable server
    /// (`{"op":"pull_bands","band":b,"gen":g}`, global band numbering;
    /// `gen` selects the generation, 0 — the oldest — when omitted, so
    /// pre-generational servers keep answering) — the anti-entropy
    /// primitive: a restarted replica OR-merges a healthy peer's words
    /// band by band, generation by generation
    /// ([`crate::engine::BandSliceIndex::merge_band_words`]) to
    /// re-converge before rejoining probe rotation. Returns the raw
    /// reply (`band`, `gen`, `generations`, `words`, `inserted`, plus
    /// the `num_bands` / `rows_per_band` geometry echo the merge
    /// validates against).
    pub fn pull_band(&mut self, band: usize, gen: usize) -> std::io::Result<Value> {
        let resp = self.round_trip(json::obj(vec![
            ("op", Value::str("pull_bands")),
            ("band", Value::u64(band as u64)),
            ("gen", Value::u64(gen as u64)),
        ]))?;
        if resp.get("error").is_some() {
            return Err(err_from(&resp));
        }
        Ok(resp)
    }

    /// Ask a [`super::DedupRouter`] to re-admit its downed backends
    /// (`{"op":"revive"}`): the router re-runs the bind-time handshake
    /// against each dead replica and marks it probe-eligible only if
    /// geometry and insert counters agree with a healthy peer of the
    /// same slice. Returns the raw reply (`revived` / `failed` address
    /// lists).
    pub fn revive(&mut self) -> std::io::Result<Value> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("revive"))]))?;
        if resp.get("error").is_some() {
            return Err(err_from(&resp));
        }
        Ok(resp)
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown(&mut self) -> std::io::Result<()> {
        let resp = self.round_trip(json::obj(vec![("op", Value::str("shutdown"))]))?;
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(())
        } else {
            Err(err_from(&resp))
        }
    }
}

fn err_from(resp: &Value) -> std::io::Error {
    let msg = resp
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap_or("malformed response")
        .to_string();
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}
