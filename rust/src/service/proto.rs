//! Shared wire-protocol plumbing for the serving tier: bounded request
//! lines and the band-vector JSON encoding used by the `check_bands`
//! ops.
//!
//! Every line-protocol reader in the tier — the dedup server and the
//! router — goes through [`read_line_bounded`]: an unbounded
//! `read_line` into a growing `String` lets one client that streams
//! bytes without ever sending a newline OOM the process, so lines are
//! capped ([`DEFAULT_MAX_LINE_BYTES`], configurable per listener) and an
//! over-long line is reported to the caller instead of accumulating.

use crate::json::Value;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};

/// Default cap on one request/response line (16 MiB): generous for a
/// `check_batch` of real documents, far below memory-exhaustion scale.
/// Configurable per listener (`serve --max-line-bytes`, `route
/// --max-line-bytes`).
pub const DEFAULT_MAX_LINE_BYTES: usize = 16 << 20;

/// Outcome of one bounded line read.
pub(crate) enum LineRead {
    /// A complete line is in the buffer (newline included, or the
    /// stream ended mid-line with bytes pending).
    Line,
    /// Clean end of stream, nothing buffered.
    Eof,
    /// The line exceeded the cap before a newline arrived; the caller
    /// should report the oversize and close — the stream position is
    /// mid-line, so no further framing is trustworthy.
    Overflow,
}

/// Read one newline-terminated line into `line`, never letting it grow
/// past `max` bytes. Partial bytes accumulate in the caller-owned
/// buffer across calls, so a read timeout (`WouldBlock`/`TimedOut`
/// propagated as `Err`) can be retried without losing input — the same
/// contract the previous unbounded `read_line` loop relied on.
pub(crate) fn read_line_bounded(
    reader: &mut impl BufRead,
    line: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    loop {
        let (consumed, complete) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(if line.is_empty() { LineRead::Eof } else { LineRead::Line });
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    line.extend_from_slice(&available[..=pos]);
                    (pos + 1, true)
                }
                None => {
                    line.extend_from_slice(available);
                    (available.len(), false)
                }
            }
        };
        reader.consume(consumed);
        if line.len() > max {
            return Ok(LineRead::Overflow);
        }
        if complete {
            return Ok(LineRead::Line);
        }
    }
}

/// The one-line error reply shape every listener in the tier uses.
pub(crate) fn error_response(msg: impl Into<String>) -> Value {
    crate::json::obj(vec![("error", Value::str(msg.into()))])
}

/// The per-connection line loop shared by both listeners (dedup server
/// and router): bounded reads, overflow → error reply + close, short
/// read-timeout polling of the shutdown flag, one JSON reply per
/// request line. `handle` returns the reply plus a close flag (the
/// router's fail-fast path closes after replying; the server always
/// passes `false`).
pub(crate) fn serve_connection<F>(
    stream: TcpStream,
    shutdown: &AtomicBool,
    max_line_bytes: usize,
    mut handle: F,
) where
    F: FnMut(&str) -> (Value, bool),
{
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    // Poll the shutdown flag between reads so idle connections do not
    // keep the accept loop joining forever after a shutdown request.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(100)))
        .ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line: Vec<u8> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // NB: on timeout, bytes read so far remain in `line` (the
        // buffer is caller-owned), so partial lines are never dropped.
        match read_line_bounded(&mut reader, &mut line, max_line_bytes) {
            Ok(LineRead::Eof) => break,
            Ok(LineRead::Line) => {}
            Ok(LineRead::Overflow) => {
                // The stream is mid-line; no further framing is
                // trustworthy, so report the cap and close.
                let msg = format!(
                    "request line exceeds the {max_line_bytes} byte cap; closing connection"
                );
                let _ = writer.write_all((error_response(msg).to_json() + "\n").as_bytes());
                let _ = writer.flush();
                break;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
        // Borrow the line in place — copying a cap-sized request just to
        // hand it to the handler would double the per-request allocation.
        if std::str::from_utf8(&line).is_ok_and(|text| text.trim().is_empty()) {
            line.clear();
            continue;
        }
        let (response, close) = match std::str::from_utf8(&line) {
            Ok(text) => handle(text),
            Err(_) => (error_response("request line is not valid UTF-8"), false),
        };
        line.clear();
        let done = shutdown.load(Ordering::SeqCst);
        if writer
            .write_all((response.to_json() + "\n").as_bytes())
            .and_then(|_| writer.flush())
            .is_err()
        {
            break;
        }
        if close || done {
            break;
        }
    }
    crate::log_debug!("connection {peer} closed");
}

/// Extract the optional `trace` field from a request (or the echoed
/// timing object from a reply). A missing, non-string, or garbled
/// field degrades to `None` — an old client or a corrupted value must
/// yield an untraced request, never a protocol error.
pub(crate) fn trace_from_request(req: &Value) -> Option<crate::obs::TraceContext> {
    req.get("trace").and_then(Value::as_str).and_then(crate::obs::TraceContext::parse)
}

/// Attach `trace` context to an outbound request object (no-op on
/// non-object values, which cannot occur for requests we build).
pub(crate) fn attach_trace(req: &mut Value, ctx: &crate::obs::TraceContext) {
    if let Value::Obj(map) = req {
        map.insert("trace".to_string(), Value::str(ctx.encode()));
    }
}

/// The server-side timing object a traced reply carries back:
/// `{"span_id": <decimal u64>, "dur_ns": <decimal u64>}` under the
/// reply's `trace` key. The exact-integer JSON tokens round-trip the
/// full 64-bit span ID.
pub(crate) fn trace_reply(span_id: u64, dur_ns: u64) -> Value {
    crate::json::obj(vec![("span_id", Value::u64(span_id)), ("dur_ns", Value::u64(dur_ns))])
}

/// Parse a reply's `trace` timing object; any malformed shape is
/// `None` (old servers simply do not send one).
pub(crate) fn trace_timing_from_reply(reply: &Value) -> Option<(u64, u64)> {
    let t = reply.get("trace")?;
    Some((t.get("span_id")?.as_u64()?, t.get("dur_ns")?.as_u64()?))
}

/// Shared handler for the `trace_dump` wire op (server and router):
/// recent traces from this process's span ring, filtered by the
/// optional `filter_op` (exact root-span name), `min_ms` (root
/// duration floor), and `limit` request fields.
pub(crate) fn trace_dump_response(req: &Value) -> Value {
    let op = req.get("filter_op").and_then(Value::as_str);
    let min_ms = req.get("min_ms").and_then(Value::as_u64).unwrap_or(0);
    let limit = req.get("limit").and_then(Value::as_u64).unwrap_or(64) as usize;
    crate::obs::trace::traces_json(op, min_ms.saturating_mul(1_000_000), limit)
}

/// Encode a band-hash vector for the `check_bands` ops. Band hashes are
/// full-width u64s; the crate's JSON keeps the exact integer token, so
/// they round-trip without the f64-mantissa loss a generic JSON layer
/// would inflict.
pub(crate) fn bands_to_json(band_hashes: &[u64]) -> Value {
    Value::Arr(band_hashes.iter().map(|&h| Value::u64(h)).collect())
}

/// Decode a band-hash vector, enforcing the index's band count — a
/// wrong-length vector would silently probe the wrong filters, so it is
/// a protocol error, not something to truncate or pad.
pub(crate) fn bands_from_json(v: &Value, expect_bands: usize) -> Result<Vec<u64>, String> {
    let Some(arr) = v.as_arr() else {
        return Err("'bands' is not an array".to_string());
    };
    if arr.len() != expect_bands {
        return Err(format!(
            "wrong band count: got {} band hashes, the index has {expect_bands} bands",
            arr.len()
        ));
    }
    let mut bands = Vec::with_capacity(arr.len());
    for (i, h) in arr.iter().enumerate() {
        let Some(h) = h.as_u64() else {
            return Err(format!("bands[{i}] is not a u64 band hash"));
        };
        bands.push(h);
    }
    Ok(bands)
}

/// Encode a filter-word snapshot for the `pull_bands` anti-entropy op
/// (same exact-u64 token discipline as band hashes: filter words are
/// full-width bit patterns and must round-trip without f64-mantissa
/// loss).
pub(crate) fn words_to_json(words: &[u64]) -> Value {
    Value::Arr(words.iter().map(|&w| Value::u64(w)).collect())
}

/// Decode a filter-word snapshot, enforcing the expected word count — a
/// wrong-length snapshot means the peer runs a different filter
/// geometry, and OR-merging it would corrupt the membership contract,
/// so it is a protocol error, never something to truncate or pad.
pub(crate) fn words_from_json(v: &Value, expect_words: usize) -> Result<Vec<u64>, String> {
    let Some(arr) = v.as_arr() else {
        return Err("'words' is not an array".to_string());
    };
    if arr.len() != expect_words {
        return Err(format!(
            "wrong word count: got {} filter words, this filter has {expect_words}",
            arr.len()
        ));
    }
    let mut words = Vec::with_capacity(arr.len());
    for (i, w) in arr.iter().enumerate() {
        let Some(w) = w.as_u64() else {
            return Err(format!("words[{i}] is not a u64 filter word"));
        };
        words.push(w);
    }
    Ok(words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    /// (line bytes, overflowed) per read until EOF or overflow.
    fn read_all(input: &[u8], max: usize) -> Vec<(Vec<u8>, bool)> {
        let mut reader = BufReader::with_capacity(8, input);
        let mut out = Vec::new();
        let mut line = Vec::new();
        loop {
            match read_line_bounded(&mut reader, &mut line, max).unwrap() {
                LineRead::Eof => break,
                LineRead::Line => out.push((std::mem::take(&mut line), false)),
                LineRead::Overflow => {
                    out.push((std::mem::take(&mut line), true));
                    break;
                }
            }
        }
        out
    }

    #[test]
    fn splits_lines_and_keeps_newlines() {
        let reads = read_all(b"one\ntwo\n", 100);
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[0].0, b"one\n");
        assert_eq!(reads[1].0, b"two\n");
    }

    #[test]
    fn final_unterminated_line_is_returned() {
        let reads = read_all(b"one\ntail", 100);
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[1].0, b"tail");
    }

    #[test]
    fn overflow_reported_once_cap_is_exceeded() {
        let reads = read_all(&[b'x'; 64], 16);
        assert_eq!(reads.len(), 1);
        assert!(reads[0].1, "must report overflow");
        // The buffer never grows far past the cap (one fill_buf chunk).
        assert!(reads[0].0.len() <= 16 + 8);
    }

    #[test]
    fn over_long_terminated_line_is_still_an_overflow() {
        let mut input = vec![b'y'; 40];
        input.push(b'\n');
        let reads = read_all(&input, 16);
        assert!(reads[0].1);
    }

    #[test]
    fn trace_field_degrades_to_untraced_never_an_error() {
        use crate::json::{obj, parse};
        // Missing field (an old client).
        let req = parse(r#"{"op":"check","text":"hi"}"#).unwrap();
        assert_eq!(trace_from_request(&req), None);
        // Garbled string, wrong type, wrong shape: all None, no panic.
        for raw in [
            r#"{"op":"check","trace":"not-a-context"}"#,
            r#"{"op":"check","trace":12345}"#,
            r#"{"op":"check","trace":{"deep":"object"}}"#,
            r#"{"op":"check","trace":null}"#,
        ] {
            assert_eq!(trace_from_request(&parse(raw).unwrap()), None, "raw {raw}");
        }
        // A well-formed context round-trips through attach_trace.
        let ctx = crate::obs::TraceContext { trace_id: 7, span_id: 9 };
        let mut req = obj(vec![("op", Value::str("check"))]);
        attach_trace(&mut req, &ctx);
        assert_eq!(trace_from_request(&req), Some(ctx));
    }

    #[test]
    fn reply_timing_roundtrips_and_tolerates_garbage() {
        use crate::json::{obj, parse};
        let mut reply = obj(vec![("ok", Value::Bool(true))]);
        if let Value::Obj(m) = &mut reply {
            m.insert("trace".to_string(), trace_reply(u64::MAX, 1234));
        }
        assert_eq!(trace_timing_from_reply(&reply), Some((u64::MAX, 1234)));
        // No timing, partial timing, or junk: None, never an error.
        assert_eq!(trace_timing_from_reply(&obj(vec![])), None);
        let bad = parse(r#"{"trace":{"span_id":"xyz","dur_ns":5}}"#).unwrap();
        assert_eq!(trace_timing_from_reply(&bad), None);
        let bad = parse(r#"{"trace":"flat string"}"#).unwrap();
        assert_eq!(trace_timing_from_reply(&bad), None);
    }

    #[test]
    fn bands_roundtrip_and_validation() {
        let bands = vec![u64::MAX, 0, 12345];
        let v = bands_to_json(&bands);
        assert_eq!(bands_from_json(&v, 3).unwrap(), bands);
        let err = bands_from_json(&v, 4).unwrap_err();
        assert!(err.contains("wrong band count"), "{err}");
        let err = bands_from_json(&Value::str("nope"), 3).unwrap_err();
        assert!(err.contains("not an array"), "{err}");
        let bad = Value::Arr(vec![Value::u64(1), Value::Bool(true), Value::u64(2)]);
        let err = bands_from_json(&bad, 3).unwrap_err();
        assert!(err.contains("bands[1]"), "{err}");
    }
}
