//! The dedup server: TCP listener + shared LSHBloom state.
//!
//! Two index backends ([`crate::config::EngineMode`]):
//!
//! * **Classic** — the sequential `LshBloomDecider` behind a mutex.
//!   MinHashing runs on connection threads; index access serializes.
//! * **Concurrent** — the lock-free [`crate::engine::ConcurrentEngine`]:
//!   both MinHashing *and* index access run on connection threads with
//!   no global lock, so ingest throughput scales with client count.
//!   Twins arriving on different connections in the same instant may
//!   both be admitted (see the `engine` module's linearizability
//!   caveat); `use_shm`/`blocked_bloom` are ignored in this mode (atomic
//!   filters are heap-resident, classic layout — the `serve` CLI rejects
//!   those flag combinations outright so operators are not misled).
//!
//! `{"op":"stats"}` is always lock-free: counters live in atomic
//! [`ServerStats`] and the index footprint is static (Bloom filters are
//! sized by planned capacity at bind time), so health checks never queue
//! behind ingest on either backend.

use crate::config::{EngineMode, PipelineConfig};
use crate::corpus::Doc;
use crate::engine::ConcurrentEngine;
use crate::json::{self, obj, Value};
use crate::methods::lshbloom::{decider_from_config, BandPreparer, LshBloomDecider};
use crate::methods::{Decider, Prepared, Preparer};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared counters exposed by `{"op":"stats"}`.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub docs: AtomicU64,
    pub duplicates: AtomicU64,
}

/// Index state behind the listener.
enum IndexBackend {
    /// Sequential decider; index access serializes on the mutex.
    Classic { preparer: BandPreparer, decider: Mutex<LshBloomDecider> },
    /// Lock-free engine; no serialization anywhere on the request path.
    Concurrent(ConcurrentEngine),
}

impl IndexBackend {
    /// Query + optional insert for one document.
    fn decide(&self, text: &str, insert: bool) -> bool {
        let doc = Doc { id: 0, text: text.to_string() };
        match self {
            IndexBackend::Classic { preparer, decider } => {
                // MinHash outside the lock (parallel across connections).
                let prepared = preparer.prepare_batch(std::slice::from_ref(&doc));
                let Prepared::Bands(ref bands) = prepared[0] else { unreachable!() };
                let mut decider = decider.lock().unwrap();
                if insert {
                    decider.decide(&prepared[0])
                } else {
                    use crate::index::BandIndex;
                    decider.index().query(bands)
                }
            }
            IndexBackend::Concurrent(engine) => {
                if insert {
                    engine.insert_one(&doc)
                } else {
                    engine.query_one(&doc)
                }
            }
        }
    }
}

struct Shared {
    backend: IndexBackend,
    /// Index footprint, captured at bind time. Bloom filters are sized by
    /// planned capacity — the footprint never changes afterwards — so
    /// stats requests can report it without touching the decider lock.
    disk_bytes: u64,
    stats: ServerStats,
    shutdown: AtomicBool,
}

/// A running deduplication service.
pub struct DedupServer {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl DedupServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, cfg: &PipelineConfig) -> std::io::Result<Self> {
        let (backend, disk_bytes) = match cfg.engine {
            EngineMode::Classic => {
                let preparer = BandPreparer::from_config(cfg);
                let decider = decider_from_config(cfg, preparer.lsh);
                let disk = decider.disk_bytes();
                (IndexBackend::Classic { preparer, decider: Mutex::new(decider) }, disk)
            }
            EngineMode::Concurrent => {
                let engine = ConcurrentEngine::from_config(cfg);
                let disk = engine.disk_bytes();
                (IndexBackend::Concurrent(engine), disk)
            }
        };
        let shared = Arc::new(Shared {
            backend,
            disk_bytes,
            stats: ServerStats::default(),
            shutdown: AtomicBool::new(false),
        });
        let listener = TcpListener::bind(addr)?;
        Ok(Self { listener, shared })
    }

    /// The bound address (for ephemeral-port tests).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve until a client sends `{"op":"shutdown"}`. Each connection
    /// gets a thread; MinHashing runs on the connection thread (parallel
    /// across clients). Index access serializes on the decider mutex in
    /// classic mode and is lock-free in concurrent mode.
    pub fn serve(self) -> std::io::Result<()> {
        // Period polling of the shutdown flag via a nonblocking accept
        // loop keeps the implementation dependency-free.
        self.listener.set_nonblocking(true)?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            // Reap completed connection threads on every loop turn;
            // keeping every JoinHandle until shutdown would grow
            // `handles` (and pin each thread's unfreed resources)
            // without bound under sustained short-lived traffic.
            handles.retain(|h| !h.is_finished());
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(stream, shared)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        // Only still-live connections remain; join them for an orderly
        // shutdown.
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    // Poll the shutdown flag between reads so idle connections do not
    // keep `serve()` joining forever after a shutdown request.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(100)))
        .ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        // NB: on timeout, bytes read so far remain in `line`; the next
        // read_line call appends, so partial lines are never dropped.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let response = handle_request(&line, &shared);
        line.clear();
        let done = shared.shutdown.load(Ordering::SeqCst);
        if writer
            .write_all((response.to_json() + "\n").as_bytes())
            .and_then(|_| writer.flush())
            .is_err()
        {
            break;
        }
        if done {
            break;
        }
    }
    crate::log_debug!("connection {peer} closed");
}

fn handle_request(line: &str, shared: &Shared) -> Value {
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return obj(vec![
                ("error", Value::str(format!("bad request json: {e}"))),
            ])
        }
    };
    match req.get("op").and_then(|v| v.as_str()) {
        Some("check") | Some("query") => {
            let insert = req.get("op").and_then(|v| v.as_str()) == Some("check");
            let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
                return obj(vec![("error", Value::str("missing 'text'"))]);
            };
            let duplicate = shared.backend.decide(text, insert);
            if insert {
                let id = shared.stats.docs.fetch_add(1, Ordering::SeqCst);
                if duplicate {
                    shared.stats.duplicates.fetch_add(1, Ordering::SeqCst);
                }
                obj(vec![
                    ("duplicate", Value::Bool(duplicate)),
                    ("id", Value::u64(id)),
                ])
            } else {
                obj(vec![("duplicate", Value::Bool(duplicate))])
            }
        }
        Some("stats") => obj(vec![
            ("docs", Value::u64(shared.stats.docs.load(Ordering::SeqCst))),
            (
                "duplicates",
                Value::u64(shared.stats.duplicates.load(Ordering::SeqCst)),
            ),
            ("disk_bytes", Value::u64(shared.disk_bytes)),
        ]),
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            obj(vec![("ok", Value::Bool(true))])
        }
        Some(other) => obj(vec![("error", Value::str(format!("unknown op '{other}'")))]),
        None => obj(vec![("error", Value::str("missing 'op'"))]),
    }
}
