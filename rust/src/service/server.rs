//! The dedup server: TCP listener + shared LSHBloom state.
//!
//! Two index backends ([`crate::config::EngineMode`]):
//!
//! * **Classic** — the sequential `LshBloomDecider` behind a mutex.
//!   MinHashing runs on connection threads; index access serializes.
//! * **Concurrent** — the lock-free [`crate::engine::ConcurrentEngine`]:
//!   both MinHashing *and* index access run on connection threads with
//!   no global lock, so ingest throughput scales with client count.
//!   Twins arriving on different connections in the same instant may
//!   both be admitted (see the `engine` module's linearizability
//!   caveat); `use_shm`/`blocked_bloom` are classic-only (the `serve`
//!   CLI rejects those flag combinations outright — concurrent
//!   persistence goes through `--state-dir` instead).
//!
//! `{"op":"stats"}` never queues behind ingest: counters live in atomic
//! [`ServerStats`], the classic footprint is captured at bind (genuinely
//! static there), and the concurrent footprint is recomputed lock-free
//! from the live engine — so a warm-started server reports its
//! *restored* index (and, with `--state-dir`, the actual persisted
//! bytes on disk) rather than a stale bind-time estimate.
//!
//! Ops: `check` / `query` (one document), `check_batch` (N documents in
//! one round trip, hitting the engine's batched fast path), `stats`,
//! `shutdown`. With [`DedupServer::bind_with_state`] the concurrent
//! index is mmap-backed in a state directory: restored on bind when a
//! checkpoint manifest is present, checkpointed again on orderly
//! shutdown. When the state dir is the aggregated output of a `dedup
//! --distributed` run, `stats` additionally reports `shard_workers` —
//! how many worker processes produced the index being served.

use crate::config::{EngineMode, PipelineConfig};
use crate::corpus::Doc;
use crate::engine::ConcurrentEngine;
use crate::json::{self, obj, Value};
use crate::methods::lshbloom::{decider_from_config, BandPreparer, LshBloomDecider};
use crate::methods::{Decider, Prepared, Preparer};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared counters exposed by `{"op":"stats"}`.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub docs: AtomicU64,
    pub duplicates: AtomicU64,
}

/// Index state behind the listener.
enum IndexBackend {
    /// Sequential decider; index access serializes on the mutex.
    Classic { preparer: BandPreparer, decider: Mutex<LshBloomDecider> },
    /// Lock-free engine; no serialization anywhere on the request path.
    Concurrent(ConcurrentEngine),
}

impl IndexBackend {
    /// Query + optional insert for one document.
    fn decide(&self, text: &str, insert: bool) -> bool {
        let doc = Doc { id: 0, text: text.to_string() };
        match self {
            IndexBackend::Classic { preparer, decider } => {
                // MinHash outside the lock (parallel across connections).
                let prepared = preparer.prepare_batch(std::slice::from_ref(&doc));
                let Prepared::Bands(ref bands) = prepared[0] else { unreachable!() };
                let mut decider = decider.lock().unwrap();
                if insert {
                    decider.decide(&prepared[0])
                } else {
                    use crate::index::BandIndex;
                    decider.index().query(bands)
                }
            }
            IndexBackend::Concurrent(engine) => {
                if insert {
                    engine.insert_one(&doc)
                } else {
                    engine.query_one(&doc)
                }
            }
        }
    }

    /// Query + insert for a whole batch (the `check_batch` op): one
    /// request, one response, N verdicts — amortizing the per-document
    /// syscall + JSON round trip the line protocol pays.
    ///
    /// * Concurrent — [`ConcurrentEngine::submit`]: the batched fast
    ///   path (pooled MinHash + lock-free probes), whose intra-batch
    ///   reconcile also catches twins *within* the batch exactly.
    /// * Classic — MinHash the whole batch outside the lock
    ///   (`prepare_batch`), then decide every document under a single
    ///   lock acquisition instead of N.
    fn decide_batch(&self, texts: &[&str]) -> Vec<bool> {
        let docs: Vec<Doc> = texts
            .iter()
            .enumerate()
            .map(|(i, t)| Doc { id: i as u64, text: (*t).to_string() })
            .collect();
        match self {
            IndexBackend::Classic { preparer, decider } => {
                let prepared = preparer.prepare_batch(&docs);
                let mut decider = decider.lock().unwrap();
                prepared.iter().map(|p| decider.decide(p)).collect()
            }
            IndexBackend::Concurrent(engine) => {
                engine.submit(docs).into_iter().map(|d| d.duplicate).collect()
            }
        }
    }
}

struct Shared {
    backend: IndexBackend,
    /// Durable state directory for a warm-startable concurrent backend
    /// (`serve --state-dir`); the orderly-shutdown checkpoint targets it.
    state_dir: Option<std::path::PathBuf>,
    /// Footprint snapshot taken at bind, used when the number is
    /// genuinely static: the classic decider's backing size, or — for a
    /// durable server — the persisted on-disk bytes (band files plus
    /// manifest when warm-started). Bind-time is the right moment to
    /// measure the directory: rescanning per stats request would put
    /// filesystem walks on the health-check path and transiently
    /// double-count `.tmp` files while a checkpoint is mid-flight. The
    /// footprint only changes again at the shutdown checkpoint, after
    /// which no stats request can observe it.
    bind_disk_bytes: u64,
    /// Worker directories with completion manifests found under the
    /// state dir at bind — nonzero exactly when this server was pointed
    /// at the aggregated output of a `dedup --distributed` run, in which
    /// case `{"op":"stats"}` reports how many shard workers produced the
    /// index being served. Counted once at bind for the same reason as
    /// `bind_disk_bytes`: the worker set cannot change while we serve.
    shard_workers: u64,
    stats: ServerStats,
    shutdown: AtomicBool,
}

impl Shared {
    /// Footprint reported by `{"op":"stats"}`: the bind-time snapshot
    /// for a durable or classic server, else recomputed lock-free from
    /// the live engine (so a warm-started server reports its *restored*
    /// index, never a stale estimate of some other index).
    fn current_disk_bytes(&self) -> u64 {
        if self.state_dir.is_some() {
            return self.bind_disk_bytes;
        }
        match &self.backend {
            IndexBackend::Classic { .. } => self.bind_disk_bytes,
            IndexBackend::Concurrent(engine) => engine.disk_bytes(),
        }
    }
}

/// Count the shard workers that produced the aggregated state in `dir`:
/// worker-000's [`crate::persist::WorkerManifest`] names the layout's
/// shard count, and every shard of that layout must be present and
/// agree. Stale `worker-*` directories left by an earlier run with a
/// *different* shard count are thereby ignored (the latest run rewrote
/// the manifests of the shards it owns); any inconsistency reads as 0
/// (unknown) rather than a wrong count.
fn count_shard_workers(dir: &std::path::Path) -> u64 {
    use crate::persist::{worker_dir_name, WorkerManifest};
    let Ok(first) = WorkerManifest::load(&dir.join(worker_dir_name(0))) else {
        return 0;
    };
    let n = first.num_shards;
    for shard in 0..n {
        match WorkerManifest::load(&dir.join(worker_dir_name(shard))) {
            Ok(m) if m.shard == shard && m.num_shards == n => {}
            _ => return 0,
        }
    }
    n as u64
}

/// Total size of the regular files directly inside `dir` (the persisted
/// checkpoint footprint: band bit files + manifest).
fn dir_file_bytes(dir: &std::path::Path) -> Option<u64> {
    let mut total = 0u64;
    for entry in std::fs::read_dir(dir).ok()? {
        let entry = entry.ok()?;
        let md = entry.metadata().ok()?;
        if md.is_file() {
            total += md.len();
        }
    }
    Some(total)
}

/// A running deduplication service.
pub struct DedupServer {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl DedupServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, cfg: &PipelineConfig) -> std::io::Result<Self> {
        Self::bind_with_state(addr, cfg, None)
    }

    /// [`Self::bind`] with a durable state directory (`serve
    /// --state-dir`, concurrent engine only): if `dir` holds a
    /// checkpoint manifest the index (and its docs/duplicates counters)
    /// is restored from it — warm start — otherwise fresh mmap-backed
    /// filters are created there. Either way the files are the live
    /// backing store, and an orderly shutdown writes a final checkpoint.
    pub fn bind_with_state(
        addr: &str,
        cfg: &PipelineConfig,
        state_dir: Option<&std::path::Path>,
    ) -> std::io::Result<Self> {
        let mut bind_disk_bytes = 0u64;
        let backend = match (cfg.engine, state_dir) {
            (EngineMode::Classic, Some(_)) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "--state-dir requires the concurrent engine \
                     (the classic index persists via LshBloomIndex::save_dir)",
                ));
            }
            (EngineMode::Classic, None) => {
                let preparer = BandPreparer::from_config(cfg);
                let decider = decider_from_config(cfg, preparer.lsh);
                bind_disk_bytes = decider.disk_bytes();
                IndexBackend::Classic { preparer, decider: Mutex::new(decider) }
            }
            (EngineMode::Concurrent, None) => {
                IndexBackend::Concurrent(ConcurrentEngine::from_config(cfg))
            }
            (EngineMode::Concurrent, Some(dir)) => {
                let engine = if crate::persist::CheckpointManifest::exists(dir) {
                    ConcurrentEngine::restore(cfg, dir, true)
                } else {
                    ConcurrentEngine::new_persistent(cfg, dir)
                }
                .map_err(|e| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                })?;
                // Persisted footprint, measured once while no checkpoint
                // can be in flight (band files exist from engine
                // construction; the manifest too on a warm start).
                bind_disk_bytes = dir_file_bytes(dir).unwrap_or_else(|| engine.disk_bytes());
                IndexBackend::Concurrent(engine)
            }
        };
        let stats = ServerStats::default();
        if let IndexBackend::Concurrent(engine) = &backend {
            // Seed the wire counters from the (possibly restored)
            // engine so a warm-started server's stats continue where
            // the previous process stopped.
            let (docs, duplicates) = engine.stats();
            stats.docs.store(docs, Ordering::SeqCst);
            stats.duplicates.store(duplicates, Ordering::SeqCst);
        }
        let shared = Arc::new(Shared {
            backend,
            state_dir: state_dir.map(|p| p.to_path_buf()),
            bind_disk_bytes,
            shard_workers: state_dir.map(count_shard_workers).unwrap_or(0),
            stats,
            shutdown: AtomicBool::new(false),
        });
        let listener = TcpListener::bind(addr)?;
        Ok(Self { listener, shared })
    }

    /// The bound address (for ephemeral-port tests).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve until a client sends `{"op":"shutdown"}`. Each connection
    /// gets a thread; MinHashing runs on the connection thread (parallel
    /// across clients). Index access serializes on the decider mutex in
    /// classic mode and is lock-free in concurrent mode.
    pub fn serve(self) -> std::io::Result<()> {
        // Period polling of the shutdown flag via a nonblocking accept
        // loop keeps the implementation dependency-free.
        self.listener.set_nonblocking(true)?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            // Reap completed connection threads on every loop turn;
            // keeping every JoinHandle until shutdown would grow
            // `handles` (and pin each thread's unfreed resources)
            // without bound under sustained short-lived traffic.
            handles.retain(|h| !h.is_finished());
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(stream, shared)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        // Only still-live connections remain; join them for an orderly
        // shutdown.
        for h in handles {
            let _ = h.join();
        }
        // Durable servers leave a complete checkpoint behind (manifest +
        // synced filters) so the next `--state-dir` bind warm-starts
        // with exact counters.
        if let (Some(dir), IndexBackend::Concurrent(engine)) =
            (&self.shared.state_dir, &self.shared.backend)
        {
            if let Err(e) = engine.checkpoint(dir) {
                crate::log_warn!("final checkpoint to {} failed: {e}", dir.display());
            }
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    // Poll the shutdown flag between reads so idle connections do not
    // keep `serve()` joining forever after a shutdown request.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(100)))
        .ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        // NB: on timeout, bytes read so far remain in `line`; the next
        // read_line call appends, so partial lines are never dropped.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let response = handle_request(&line, &shared);
        line.clear();
        let done = shared.shutdown.load(Ordering::SeqCst);
        if writer
            .write_all((response.to_json() + "\n").as_bytes())
            .and_then(|_| writer.flush())
            .is_err()
        {
            break;
        }
        if done {
            break;
        }
    }
    crate::log_debug!("connection {peer} closed");
}

fn handle_request(line: &str, shared: &Shared) -> Value {
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return obj(vec![
                ("error", Value::str(format!("bad request json: {e}"))),
            ])
        }
    };
    match req.get("op").and_then(|v| v.as_str()) {
        Some("check") | Some("query") => {
            let insert = req.get("op").and_then(|v| v.as_str()) == Some("check");
            let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
                return obj(vec![("error", Value::str("missing 'text'"))]);
            };
            let duplicate = shared.backend.decide(text, insert);
            if insert {
                let id = shared.stats.docs.fetch_add(1, Ordering::SeqCst);
                if duplicate {
                    shared.stats.duplicates.fetch_add(1, Ordering::SeqCst);
                }
                obj(vec![
                    ("duplicate", Value::Bool(duplicate)),
                    ("id", Value::u64(id)),
                ])
            } else {
                obj(vec![("duplicate", Value::Bool(duplicate))])
            }
        }
        Some("check_batch") => {
            let Some(texts_json) = req.get("texts").and_then(|v| v.as_arr()) else {
                return obj(vec![("error", Value::str("missing 'texts' array"))]);
            };
            let mut texts = Vec::with_capacity(texts_json.len());
            for (i, t) in texts_json.iter().enumerate() {
                let Some(s) = t.as_str() else {
                    return obj(vec![(
                        "error",
                        Value::str(format!("texts[{i}] is not a string")),
                    )]);
                };
                texts.push(s);
            }
            let verdicts = shared.backend.decide_batch(&texts);
            let first_id = shared.stats.docs.fetch_add(texts.len() as u64, Ordering::SeqCst);
            let dups = verdicts.iter().filter(|&&d| d).count() as u64;
            shared.stats.duplicates.fetch_add(dups, Ordering::SeqCst);
            obj(vec![
                (
                    "duplicates",
                    Value::Arr(verdicts.into_iter().map(Value::Bool).collect()),
                ),
                (
                    "ids",
                    Value::Arr(
                        (0..texts.len() as u64).map(|i| Value::u64(first_id + i)).collect(),
                    ),
                ),
            ])
        }
        Some("stats") => obj(vec![
            ("docs", Value::u64(shared.stats.docs.load(Ordering::SeqCst))),
            (
                "duplicates",
                Value::u64(shared.stats.duplicates.load(Ordering::SeqCst)),
            ),
            ("disk_bytes", Value::u64(shared.current_disk_bytes())),
            ("shard_workers", Value::u64(shared.shard_workers)),
        ]),
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            obj(vec![("ok", Value::Bool(true))])
        }
        Some(other) => obj(vec![("error", Value::str(format!("unknown op '{other}'")))]),
        None => obj(vec![("error", Value::str("missing 'op'"))]),
    }
}
