//! The dedup server: TCP listener + shared LSHBloom state.
//!
//! Four index backends:
//!
//! * **Classic** — the sequential `LshBloomDecider` behind a mutex.
//!   MinHashing runs on connection threads; index access serializes.
//! * **Concurrent** — the lock-free [`crate::engine::ConcurrentEngine`]:
//!   both MinHashing *and* index access run on connection threads with
//!   no global lock, so ingest throughput scales with client count.
//!   Twins arriving on different connections in the same instant may
//!   both be admitted (see the `engine` module's linearizability
//!   caveat); `use_shm`/`blocked_bloom` are classic-only (the `serve`
//!   CLI rejects those flag combinations outright — concurrent
//!   persistence goes through `--state-dir` instead).
//! * **BandSharded** (`serve --serve-shards N`) — the band-partitioned
//!   serving tier in one process: N
//!   [`crate::engine::BandSliceIndex`] slices behind one preparer
//!   ([`crate::engine::BandShardedEngine`]). A request MinHashes once,
//!   every slice is probed and the per-slice verdicts OR-reduce, which
//!   preserves single-engine semantics exactly (a duplicate iff *any*
//!   band collides).
//! * **Slice** (`serve --slice-index I --slice-count N`) — one band
//!   slice served alone: the multi-host backend a
//!   [`super::DedupRouter`] fans band-level ops across. Text ops are
//!   rejected (a lone slice cannot answer them correctly); the slice
//!   accepts `check_bands`/`check_bands_batch`, whose band vectors were
//!   MinHashed once at the router.
//!
//! `{"op":"stats"}` never queues behind ingest: counters live in atomic
//! [`ServerStats`], the classic footprint is captured at bind (genuinely
//! static there), and the concurrent footprint is recomputed lock-free
//! from the live engine — so a warm-started server reports its
//! *restored* index (and, with `--state-dir`, the actual persisted
//! bytes on disk) rather than a stale bind-time estimate. Stats also
//! reports the band layout (`num_bands`, `slice_index`, `slice_count`)
//! so a router can fail fast on a misconfigured backend fleet.
//!
//! Ops: `check` / `query` (one document), `check_batch` (N documents in
//! one round trip, hitting the engine's batched fast path),
//! `check_bands` / `check_bands_batch` (pre-MinHashed band vectors from
//! a router — concurrent-family backends only), `stats`, `metrics`
//! (the full [`crate::obs`] registry as JSON, fill gauges refreshed
//! first), `trace_dump` (recent traces from the
//! [`crate::obs::trace`] ring), `shutdown`. With `--metrics-addr` the
//! same registry is also scrapeable as Prometheus text over a minimal
//! HTTP listener (plus `/healthz`, `/readyz`, and the `/debug/traces`
//! explorer); request latency for the dedup ops feeds
//! `server.request.seconds` (aggregate and per-op), with an in-flight
//! gauge and request/error counters alongside.
//!
//! Every request runs under a [`crate::obs::trace`] root span: adopted
//! from the request's `trace` field when a router (or traced client)
//! supplied one, minted locally otherwise, sampled per
//! `--trace-sample` / forced by errors and `--trace-slow-ms`. Replies
//! to traced requests carry a `trace` object with this server's span
//! ID and self-measured duration so the caller can attribute wire time
//! vs server time per hop.
//! Request lines are capped ([`super::DEFAULT_MAX_LINE_BYTES`],
//! `--max-line-bytes`): a client that streams bytes without a newline
//! gets an error response and a closed connection instead of growing a
//! buffer without bound.
//!
//! With [`DedupServer::bind_with_state`] the concurrent index is
//! mmap-backed in a state directory: restored on bind when a checkpoint
//! manifest is present, checkpointed again on orderly shutdown. A
//! band-sharded server warm-starts each slice from the same full-index
//! manifest (slice-aware restore) and writes a full-index snapshot back
//! on shutdown. A slice server *owns* its state dir as live mmaps
//! ([`crate::engine::BandSliceIndex::open_durable`]): every insert is
//! durable before it is acknowledged, a crash-restart loses nothing,
//! and the shutdown checkpoint refreshes only the slice's own manifest
//! entries. `--sync-from PEERS` re-converges a restarted replica by
//! OR-merging a healthy peer's filters (the `pull_bands` op) before the
//! listener accepts traffic. When the state dir is the aggregated
//! output of a `dedup --distributed` run, `stats` additionally reports
//! `shard_workers` — how many worker processes produced the index being
//! served.

use super::proto::{bands_from_json, error_response};
use super::DEFAULT_MAX_LINE_BYTES;
use crate::config::{EngineMode, PipelineConfig};
use crate::corpus::Doc;
use crate::engine::{BandShardedEngine, BandSliceIndex, ConcurrentEngine};
use crate::index::lshbloom::LshBloomConfig;
use crate::json::{self, obj, Value};
use crate::methods::lshbloom::{decider_from_config, BandPreparer, LshBloomDecider};
use crate::methods::{Decider, Prepared, Preparer};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared counters exposed by `{"op":"stats"}`.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub docs: AtomicU64,
    pub duplicates: AtomicU64,
}

/// Listener-level options beyond the pipeline config.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Durable state directory. Concurrent / band-sharded backends
    /// warm-start from its checkpoint when present and checkpoint on
    /// orderly shutdown. A slice server *owns* it as live mmap-backed
    /// state ([`crate::engine::BandSliceIndex::open_durable`]): every
    /// insert is on disk before it is acknowledged, so a crashed slice
    /// restarts with zero lost inserts.
    pub state_dir: Option<std::path::PathBuf>,
    /// Serve one band slice `(index, count)` as a router backend
    /// instead of a full index. Mutually exclusive with
    /// `cfg.serve_shards > 1`.
    pub slice: Option<(usize, usize)>,
    /// Peer slice-server addresses to anti-entropy-pull from at bind
    /// (`serve --sync-from`, slice mode only): the owned bands are
    /// OR-merged from the first answering peer via `pull_bands` before
    /// the listener accepts traffic, so a restarted replica re-converges
    /// with its replica set before the router's handshake can see it.
    pub sync_from: Vec<String>,
    /// Per-connection request-line cap in bytes
    /// ([`DEFAULT_MAX_LINE_BYTES`] unless overridden).
    pub max_line_bytes: usize,
    /// `HOST:PORT` for the Prometheus metrics endpoint
    /// (`serve --metrics-addr`); `None` disables it. Port 0 binds an
    /// ephemeral port (see [`DedupServer::metrics_addr`]).
    pub metrics_addr: Option<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            state_dir: None,
            slice: None,
            sync_from: Vec::new(),
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            metrics_addr: None,
        }
    }
}

/// Index state behind the listener.
enum IndexBackend {
    /// Sequential decider; index access serializes on the mutex.
    Classic { preparer: BandPreparer, decider: Mutex<LshBloomDecider> },
    /// Lock-free engine; no serialization anywhere on the request path.
    Concurrent(ConcurrentEngine),
    /// N in-process band slices behind one preparer (`--serve-shards`).
    BandSharded(BandShardedEngine),
    /// One band slice, band-level ops only (router backend).
    Slice { index: BandSliceIndex, slice: usize, count: usize },
}

impl IndexBackend {
    /// Full band count of the index this server partitions or serves.
    fn num_bands(&self) -> usize {
        match self {
            IndexBackend::Classic { preparer, .. } => preparer.lsh.num_bands,
            IndexBackend::Concurrent(engine) => engine.index().num_bands(),
            IndexBackend::BandSharded(engine) => engine.num_bands(),
            IndexBackend::Slice { index, .. } => index.full_bands(),
        }
    }

    /// (slice index, slice count) for the stats handshake; a full
    /// server is slice 0 of 1.
    fn slice_layout(&self) -> (usize, usize) {
        match self {
            IndexBackend::Slice { slice, count, .. } => (*slice, *count),
            _ => (0, 1),
        }
    }

    /// Rows hashed per band — the other half of the index geometry the
    /// router's handshake must verify: two perm counts can derive the
    /// same band count with different rows, which band count alone
    /// would wave through (and then every probe would silently miss).
    fn rows_per_band(&self) -> usize {
        match self {
            IndexBackend::Classic { preparer, .. } => preparer.lsh.rows_per_band,
            IndexBackend::Concurrent(engine) => engine.index().config().lsh.rows_per_band,
            IndexBackend::BandSharded(engine) => engine.rows_per_band(),
            IndexBackend::Slice { index, .. } => index.config().lsh.rows_per_band,
        }
    }

    /// Whether this backend serves the band-level ops a router fans out
    /// (everything but the classic engine) — exposed in stats so a
    /// router can reject a text-only backend at bind instead of failing
    /// on the first routed request.
    fn supports_band_ops(&self) -> bool {
        !matches!(self, IndexBackend::Classic { .. })
    }

    /// Documents inserted into the live index — the counter the router's
    /// replica handshake compares across replicas of one slice (equal
    /// counters + identical insert streams ⇒ identical filters). `None`
    /// for the classic backend: reading its counter would take the
    /// decider lock, and stats must never queue behind ingest.
    fn inserted(&self) -> Option<u64> {
        match self {
            IndexBackend::Classic { .. } => None,
            IndexBackend::Concurrent(engine) => Some(engine.index().len()),
            IndexBackend::BandSharded(engine) => Some(engine.stats().0),
            IndexBackend::Slice { index, .. } => Some(index.len()),
        }
    }

    /// Generations the live index holds (1 until a rotation or a
    /// generational restore) — compared across replicas of one slice by
    /// the router's handshake, alongside `inserted`: two replicas with
    /// different generation layouts cannot have absorbed the same
    /// rotation history. `None` for the classic backend, which cannot
    /// rotate.
    fn generations(&self) -> Option<u64> {
        match self {
            IndexBackend::Classic { .. } => None,
            IndexBackend::Concurrent(engine) => Some(engine.index().num_generations() as u64),
            IndexBackend::BandSharded(engine) => Some(engine.num_generations() as u64),
            IndexBackend::Slice { index, .. } => Some(index.num_generations() as u64),
        }
    }

    /// Query + optional insert for one document.
    fn decide(&self, text: &str, insert: bool) -> Result<bool, String> {
        let doc = Doc { id: 0, text: text.to_string() };
        match self {
            IndexBackend::Classic { preparer, decider } => {
                // MinHash outside the lock (parallel across connections).
                let prepared = preparer.prepare_batch(std::slice::from_ref(&doc));
                let Prepared::Bands(ref bands) = prepared[0] else { unreachable!() };
                // Poison recovery is sound here: the decider's filter
                // state is monotone (bits only get set), so a panic in
                // another handler cannot leave it half-updated in a way
                // that corrupts later verdicts — and killing the serving
                // thread over it would turn one bad request into an
                // outage.
                let mut decider = decider.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                if insert {
                    Ok(decider.decide(&prepared[0]))
                } else {
                    use crate::index::BandIndex;
                    Ok(decider.index().query(bands))
                }
            }
            IndexBackend::Concurrent(engine) => {
                if insert {
                    Ok(engine.insert_one(&doc))
                } else {
                    Ok(engine.query_one(&doc))
                }
            }
            IndexBackend::BandSharded(engine) => {
                if insert {
                    Ok(engine.insert_one(&doc))
                } else {
                    Ok(engine.query_one(&doc))
                }
            }
            IndexBackend::Slice { .. } => Err(self.slice_rejects_text()),
        }
    }

    /// Query + insert for a whole batch (the `check_batch` op): one
    /// request, one response, N verdicts — amortizing the per-document
    /// syscall + JSON round trip the line protocol pays.
    ///
    /// * Concurrent / BandSharded — the batched fast path (pooled
    ///   MinHash + lock-free probes) whose intra-batch reconcile also
    ///   catches twins *within* the batch exactly.
    /// * Classic — MinHash the whole batch outside the lock
    ///   (`prepare_batch`), then decide every document under a single
    ///   lock acquisition instead of N.
    fn decide_batch(&self, texts: &[&str]) -> Result<Vec<bool>, String> {
        let docs: Vec<Doc> = texts
            .iter()
            .enumerate()
            .map(|(i, t)| Doc { id: i as u64, text: (*t).to_string() })
            .collect();
        match self {
            IndexBackend::Classic { preparer, decider } => {
                let prepared = preparer.prepare_batch(&docs);
                // Same poison-recovery rationale as `decide` above.
                let mut decider = decider.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                Ok(prepared.iter().map(|p| decider.decide(p)).collect())
            }
            IndexBackend::Concurrent(engine) => {
                Ok(engine.submit(docs).into_iter().map(|d| d.duplicate).collect())
            }
            IndexBackend::BandSharded(engine) => {
                Ok(engine.submit(docs).into_iter().map(|d| d.duplicate).collect())
            }
            IndexBackend::Slice { .. } => Err(self.slice_rejects_text()),
        }
    }

    /// Band-level query + optional insert (`check_bands`): the document
    /// was MinHashed once elsewhere (a router); this index contributes
    /// the verdict of the bands it owns.
    fn decide_bands(&self, bands: &[u64], insert: bool) -> Result<bool, String> {
        match self {
            IndexBackend::Classic { .. } => Err(Self::classic_rejects_bands()),
            IndexBackend::Concurrent(engine) => {
                if insert {
                    Ok(engine.insert_bands(bands))
                } else {
                    Ok(engine.query_bands(bands))
                }
            }
            IndexBackend::BandSharded(engine) => {
                if insert {
                    Ok(engine.insert_bands(bands))
                } else {
                    Ok(engine.query_bands(bands))
                }
            }
            IndexBackend::Slice { index, .. } => {
                if insert {
                    Ok(index.insert_if_new(bands))
                } else {
                    Ok(index.query(bands))
                }
            }
        }
    }

    /// Band-level batch (`check_bands_batch`): probe the whole batch
    /// read-only against pre-batch state, then insert — returning the
    /// *pre-batch* verdicts for the caller's intra-batch reconcile (see
    /// [`crate::engine::reconcile_in_batch`]).
    fn probe_insert_bands(&self, batch: &[Vec<u64>]) -> Result<Vec<bool>, String> {
        match self {
            IndexBackend::Classic { .. } => Err(Self::classic_rejects_bands()),
            IndexBackend::Concurrent(engine) => Ok(engine.probe_insert_bands(batch)),
            IndexBackend::BandSharded(engine) => Ok(engine.probe_insert_bands(batch)),
            IndexBackend::Slice { index, .. } => Ok(index.probe_insert_batch(batch)),
        }
    }

    fn slice_rejects_text(&self) -> String {
        let (slice, count) = self.slice_layout();
        format!(
            "this server owns band slice {slice} of {count}; it accepts band-level \
             ops ('check_bands', 'check_bands_batch') from a router — send text ops \
             to a full server or a router"
        )
    }

    fn classic_rejects_bands() -> String {
        "band-level ops require a concurrent-family backend (--engine concurrent, \
         --serve-shards, or --slice-index); the classic engine serves text ops only"
            .to_string()
    }
}

struct Shared {
    backend: IndexBackend,
    /// Durable state directory (`serve --state-dir`); the
    /// orderly-shutdown checkpoint targets it. A slice backend's band
    /// files live here as mmaps and its checkpoint is a
    /// read-modify-write of only its own manifest entries.
    state_dir: Option<std::path::PathBuf>,
    /// Footprint snapshot taken at bind, used when the number is
    /// genuinely static: the classic decider's backing size, or — for a
    /// durable server — the persisted on-disk bytes (band files plus
    /// manifest when warm-started). Bind-time is the right moment to
    /// measure the directory: rescanning per stats request would put
    /// filesystem walks on the health-check path while a checkpoint is
    /// mid-flight. The footprint only changes again at the shutdown
    /// checkpoint, after which no stats request can observe it.
    bind_disk_bytes: u64,
    /// Worker directories with completion manifests found under the
    /// state dir at bind — nonzero exactly when this server was pointed
    /// at the aggregated output of a `dedup --distributed` run, in which
    /// case `{"op":"stats"}` reports how many shard workers produced the
    /// index being served. Counted once at bind for the same reason as
    /// `bind_disk_bytes`: the worker set cannot change while we serve.
    shard_workers: u64,
    /// Per-connection request-line cap.
    max_line_bytes: usize,
    /// Tracing knobs (`--trace-sample`, `--trace-slow-ms`), per server
    /// instance so in-process fleets with different settings coexist.
    trace: crate::obs::TraceParams,
    stats: ServerStats,
    shutdown: AtomicBool,
}

impl Shared {
    /// Footprint reported by `{"op":"stats"}`: the bind-time snapshot
    /// for a durable or classic server, else recomputed lock-free from
    /// the live backend (so a warm-started server reports its *restored*
    /// index, never a stale estimate of some other index).
    fn current_disk_bytes(&self) -> u64 {
        if self.state_dir.is_some() {
            return self.bind_disk_bytes;
        }
        match &self.backend {
            IndexBackend::Classic { .. } => self.bind_disk_bytes,
            IndexBackend::Concurrent(engine) => engine.disk_bytes(),
            IndexBackend::BandSharded(engine) => engine.disk_bytes(),
            IndexBackend::Slice { index, .. } => index.disk_bytes(),
        }
    }

    /// Refresh the per-band fill-ratio / estimated-FP gauges from the
    /// live filters. Runs on demand — per Prometheus scrape and per
    /// `{"op":"metrics"}` — rather than per request: a sampled popcount
    /// is cheap, but not check-batch-path cheap.
    fn refresh_gauges(&self) {
        match &self.backend {
            IndexBackend::Classic { .. } => {}
            IndexBackend::Concurrent(engine) => engine.index().refresh_fill_gauges(),
            IndexBackend::BandSharded(engine) => engine.refresh_fill_gauges(),
            IndexBackend::Slice { index, .. } => index.refresh_fill_gauges(),
        }
    }
}

/// Count the shard workers that produced the aggregated state in `dir`:
/// worker-000's [`crate::persist::WorkerManifest`] names the layout's
/// shard count, and every shard of that layout must be present and
/// agree. Stale `worker-*` directories left by an earlier run with a
/// *different* shard count are thereby ignored (the latest run rewrote
/// the manifests of the shards it owns); any inconsistency reads as 0
/// (unknown) rather than a wrong count.
fn count_shard_workers(dir: &std::path::Path) -> u64 {
    use crate::persist::{worker_dir_name, WorkerManifest};
    let Ok(first) = WorkerManifest::load(&dir.join(worker_dir_name(0))) else {
        return 0;
    };
    let n = first.num_shards;
    if first.shard != 0 || n == 0 {
        return 0;
    }
    // Worker-000's manifest is already in hand (and checked above) —
    // the layout sweep starts at shard 1 instead of loading and
    // re-parsing the same file twice.
    for shard in 1..n {
        match WorkerManifest::load(&dir.join(worker_dir_name(shard))) {
            Ok(m) if m.shard == shard && m.num_shards == n => {}
            _ => return 0,
        }
    }
    n as u64
}

/// Total size of the regular files directly inside `dir` (the persisted
/// checkpoint footprint: band bit files + manifest). `*.tmp` entries are
/// skipped: the atomic-publish idiom (write `<name>.tmp`, fsync, rename)
/// can leave a stale temp behind after a torn checkpoint, and that
/// garbage — overwritten by the next checkpoint, never restored from —
/// would otherwise inflate the reported persisted footprint.
fn dir_file_bytes(dir: &std::path::Path) -> Option<u64> {
    let mut total = 0u64;
    for entry in std::fs::read_dir(dir).ok()? {
        let entry = entry.ok()?;
        if entry.file_name().to_str().is_some_and(|n| n.ends_with(".tmp")) {
            continue;
        }
        let md = entry.metadata().ok()?;
        if md.is_file() {
            total += md.len();
        }
    }
    Some(total)
}

fn invalid_input(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidInput, msg.into())
}

/// A running deduplication service.
pub struct DedupServer {
    listener: TcpListener,
    shared: Arc<Shared>,
    /// Prometheus scrape endpoint (`--metrics-addr`); owned here so it
    /// lives exactly as long as the server and stops when `serve`
    /// returns.
    metrics: Option<crate::obs::MetricsHttp>,
}

impl DedupServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, cfg: &PipelineConfig) -> std::io::Result<Self> {
        Self::bind_with_opts(addr, cfg, &ServeOptions::default())
    }

    /// [`Self::bind`] with a durable state directory (`serve
    /// --state-dir`): if `dir` holds a checkpoint manifest the index
    /// (and its docs/duplicates counters) is restored from it — warm
    /// start — otherwise fresh state is created there. Either way an
    /// orderly shutdown writes a final checkpoint.
    pub fn bind_with_state(
        addr: &str,
        cfg: &PipelineConfig,
        state_dir: Option<&std::path::Path>,
    ) -> std::io::Result<Self> {
        let opts = ServeOptions {
            state_dir: state_dir.map(|p| p.to_path_buf()),
            ..ServeOptions::default()
        };
        Self::bind_with_opts(addr, cfg, &opts)
    }

    /// The fully general constructor: state directory, band-slice mode,
    /// and the request-line cap (see [`ServeOptions`]). `cfg.serve_shards
    /// > 1` selects the in-process band-sharded backend.
    pub fn bind_with_opts(
        addr: &str,
        cfg: &PipelineConfig,
        opts: &ServeOptions,
    ) -> std::io::Result<Self> {
        let state_dir = opts.state_dir.as_deref();
        let mut bind_disk_bytes = 0u64;
        // Slice mode and classic+state-dir are rejected up front; the
        // remaining combinations pick a backend below.
        if opts.slice.is_some() && cfg.serve_shards > 1 {
            return Err(invalid_input(
                "--slice-index (one slice of a multi-host deployment) and \
                 --serve-shards (all slices in this process) are mutually exclusive",
            ));
        }
        let backend = if let Some((slice, count)) = opts.slice {
            let index_cfg = slice_mode_config(cfg, slice, count)?;
            let mut index = match state_dir {
                // Durable slice: the owned band files are live mmaps in
                // the state dir (fresh zeroed state, a previous durable
                // slice's files, or a full-index checkpoint — e.g. a
                // `dedup --distributed` aggregate — whose owned bands
                // are adopted in place), so a SIGKILL loses no inserts.
                Some(dir) => BandSliceIndex::open_durable(index_cfg, dir, slice, count)
                    .map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    })?,
                None => BandSliceIndex::new(index_cfg, slice, count),
            };
            // Anti-entropy pull before the listener accepts traffic: a
            // restarted replica OR-merges the inserts it missed from a
            // healthy peer, so by the time the router's handshake (or a
            // revive probe) reaches this process it already converged.
            if !opts.sync_from.is_empty() {
                sync_slice_from_peers(&mut index, &opts.sync_from)?;
                if let Some(dir) = state_dir {
                    // Bits merged into pre-existing generations are
                    // already durable (they landed in the mmap);
                    // generations the peer rotated past this replica
                    // were merged into fresh heap filters, and this
                    // checkpoint cold-copies them out alongside the
                    // refreshed manifest counters.
                    index.checkpoint(dir, 0, 0).map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    })?;
                }
            }
            bind_disk_bytes = match state_dir {
                Some(dir) => dir_file_bytes(dir).unwrap_or_else(|| index.disk_bytes()),
                None => index.disk_bytes(),
            };
            IndexBackend::Slice { index, slice, count }
        } else if cfg.serve_shards > 1 {
            let engine = match state_dir {
                Some(dir) if crate::persist::CheckpointManifest::exists(dir) => {
                    BandShardedEngine::restore(cfg, dir, cfg.serve_shards).map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    })?
                }
                _ => BandShardedEngine::from_config(cfg, cfg.serve_shards),
            };
            if cfg.serve_shards > engine.num_bands() {
                return Err(invalid_input(format!(
                    "--serve-shards {} exceeds the band count ({} bands at this \
                     threshold/perms geometry); extra slices would own no bands",
                    cfg.serve_shards,
                    engine.num_bands()
                )));
            }
            if let Some(dir) = state_dir {
                bind_disk_bytes = dir_file_bytes(dir).unwrap_or_else(|| engine.disk_bytes());
            }
            IndexBackend::BandSharded(engine)
        } else {
            match (cfg.engine, state_dir) {
                (EngineMode::Classic, Some(_)) => {
                    return Err(invalid_input(
                        "--state-dir requires the concurrent engine \
                         (the classic index persists via LshBloomIndex::save_dir)",
                    ));
                }
                (EngineMode::Classic, None) => {
                    let preparer = BandPreparer::from_config(cfg);
                    let decider = decider_from_config(cfg, preparer.lsh);
                    bind_disk_bytes = decider.disk_bytes();
                    IndexBackend::Classic { preparer, decider: Mutex::new(decider) }
                }
                (EngineMode::Concurrent, None) => {
                    IndexBackend::Concurrent(ConcurrentEngine::from_config(cfg))
                }
                (EngineMode::Concurrent, Some(dir)) => {
                    let engine = if crate::persist::CheckpointManifest::exists(dir) {
                        ConcurrentEngine::restore(cfg, dir, true)
                    } else {
                        ConcurrentEngine::new_persistent(cfg, dir)
                    }
                    .map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    })?;
                    // Persisted footprint, measured once while no
                    // checkpoint can be in flight (band files exist from
                    // engine construction; the manifest too on a warm
                    // start).
                    bind_disk_bytes = dir_file_bytes(dir).unwrap_or_else(|| engine.disk_bytes());
                    IndexBackend::Concurrent(engine)
                }
            }
        };
        let stats = ServerStats::default();
        // Seed the wire counters from the (possibly restored) backend so
        // a warm-started server's stats continue where the previous
        // process stopped. Slice backends start at zero: their counters
        // mean "band ops served by this slice", not corpus history.
        let seeded = match &backend {
            IndexBackend::Concurrent(engine) => Some(engine.stats()),
            IndexBackend::BandSharded(engine) => Some(engine.stats()),
            _ => None,
        };
        if let Some((docs, duplicates)) = seeded {
            stats.docs.store(docs, Ordering::SeqCst);
            stats.duplicates.store(duplicates, Ordering::SeqCst);
        }
        // Every durable backend owns its state dir now — a slice's
        // shutdown checkpoint is read-modify-write over the shared
        // manifest (`write_slice_checkpoint`), so it refreshes only its
        // own band entries and cannot clobber a sibling's.
        let owned_state_dir = opts.state_dir.clone();
        let shard_workers = owned_state_dir.as_deref().map(count_shard_workers).unwrap_or(0);
        let shared = Arc::new(Shared {
            backend,
            state_dir: owned_state_dir,
            bind_disk_bytes,
            shard_workers,
            max_line_bytes: opts.max_line_bytes,
            trace: crate::obs::TraceParams {
                sample: cfg.trace_sample,
                slow_ms: cfg.trace_slow_ms,
            },
            stats,
            shutdown: AtomicBool::new(false),
        });
        // Anchor the uptime clock before the first stats/metrics request
        // can observe it.
        crate::obs::init();
        let metrics = match &opts.metrics_addr {
            Some(maddr) => {
                // Each scrape refreshes the fill/FP gauges first, so
                // Prometheus always sees filter state no staler than the
                // scrape itself.
                let hook_shared = Arc::clone(&shared);
                // A server is ready the moment it is bound: its index
                // is local, so liveness and readiness coincide (unlike
                // the router, whose readiness tracks its backend fleet).
                Some(crate::obs::MetricsHttp::bind(
                    maddr,
                    Some(Box::new(move || hook_shared.refresh_gauges())),
                    Some(Box::new(|| true)),
                )?)
            }
            None => None,
        };
        let listener = TcpListener::bind(addr)?;
        Ok(Self { listener, shared, metrics })
    }

    /// The bound address (for ephemeral-port tests).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// The bound metrics-endpoint address, when `metrics_addr` was set
    /// (resolves port 0 to the ephemeral port actually bound).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.metrics.as_ref().map(|m| m.local_addr())
    }

    /// Serve until a client sends `{"op":"shutdown"}`. Each connection
    /// gets a thread; MinHashing runs on the connection thread (parallel
    /// across clients). Index access serializes on the decider mutex in
    /// classic mode and is lock-free otherwise.
    pub fn serve(self) -> std::io::Result<()> {
        // Period polling of the shutdown flag via a nonblocking accept
        // loop keeps the implementation dependency-free.
        self.listener.set_nonblocking(true)?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            // Reap completed connection threads on every loop turn;
            // keeping every JoinHandle until shutdown would grow
            // `handles` (and pin each thread's unfreed resources)
            // without bound under sustained short-lived traffic.
            handles.retain(|h| !h.is_finished());
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(stream, shared)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        // Only still-live connections remain; join them for an orderly
        // shutdown.
        for h in handles {
            let _ = h.join();
        }
        // Durable servers leave a complete checkpoint behind (manifest +
        // synced filters) so the next `--state-dir` bind warm-starts
        // with exact counters. A durable slice msyncs its live band
        // files and refreshes only its own manifest entries.
        if let Some(dir) = &self.shared.state_dir {
            let result = match &self.shared.backend {
                IndexBackend::Concurrent(engine) => Some(engine.checkpoint(dir)),
                IndexBackend::BandSharded(engine) => Some(engine.checkpoint(dir)),
                IndexBackend::Slice { index, .. } => Some(index.checkpoint(
                    dir,
                    self.shared.stats.docs.load(Ordering::SeqCst),
                    self.shared.stats.duplicates.load(Ordering::SeqCst),
                )),
                IndexBackend::Classic { .. } => None,
            };
            if let Some(Err(e)) = result {
                crate::log_warn!("final checkpoint to {} failed: {e}", dir.display());
            }
        }
        Ok(())
    }
}

/// Derive the full-index config for slice mode, validating the slice
/// coordinates against the engine family and band geometry.
fn slice_mode_config(
    cfg: &PipelineConfig,
    slice: usize,
    count: usize,
) -> std::io::Result<LshBloomConfig> {
    if cfg.engine != EngineMode::Concurrent {
        return Err(invalid_input(
            "--slice-index requires --engine concurrent (band slices are atomic \
             filters; the classic engine cannot host one)",
        ));
    }
    if count == 0 || slice >= count {
        return Err(invalid_input(format!(
            "slice index {slice} out of range for slice count {count}"
        )));
    }
    let lsh = crate::minhash::optimal_param(cfg.threshold, cfg.num_perms);
    let index_cfg = LshBloomConfig::new(lsh, cfg.p_effective, cfg.expected_docs);
    if count > index_cfg.lsh.num_bands {
        return Err(invalid_input(format!(
            "slice count {count} exceeds the band count ({} bands at this \
             threshold/perms geometry); extra slices would own no bands",
            index_cfg.lsh.num_bands
        )));
    }
    Ok(index_cfg)
}

/// Anti-entropy pull (`serve --sync-from`): OR-merge every owned band —
/// of every generation the peer holds — from the first peer that
/// completes the walk. A peer that rotated past this replica grows the
/// local generation list first
/// ([`BandSliceIndex::ensure_generations`]), so a restart that missed a
/// rotation converges to the peer's full layout. Transport failures
/// move on to the next peer; a *reachable* peer with mismatched
/// geometry is a hard bind error (merging it would corrupt the filter
/// contract — that is operator error, not a transient fault). Safe to
/// re-run after any interruption: the merge is a bit-OR, so replay is
/// idempotent.
fn sync_slice_from_peers(index: &mut BandSliceIndex, peers: &[String]) -> std::io::Result<()> {
    use super::DedupClient;
    // Fault-injection hook for the chaos suite: die mid-merge once the
    // cumulative merged insert count crosses the threshold, so tests can
    // prove the retried merge converges to the same bits.
    let crash_after_docs: u64 = std::env::var("LSHBLOOM_REPLICA_CRASH_AFTER_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let expect_words =
        crate::index::LshBloomIndex::filter_params(&index.config()).bits.div_ceil(64) as usize;
    let mut last_err = String::from("no peers given");
    for addr in peers {
        let mut client = match DedupClient::connect_with_timeouts(
            addr,
            std::time::Duration::from_secs(5),
            std::time::Duration::from_secs(30),
        ) {
            Ok(c) => c,
            Err(e) => {
                last_err = format!("sync peer {addr}: {e}");
                crate::log_warn!("{last_err}");
                continue;
            }
        };
        let stats = match client.stats_json() {
            Ok(s) => s,
            Err(e) => {
                last_err = format!("sync peer {addr}: stats failed: {e}");
                crate::log_warn!("{last_err}");
                continue;
            }
        };
        let peer_bands = stats.get("num_bands").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
        let peer_rows =
            stats.get("rows_per_band").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
        if peer_bands != index.full_bands() || peer_rows != index.config().lsh.rows_per_band {
            return Err(invalid_input(format!(
                "sync peer {addr} serves a different index geometry ({peer_bands} bands x \
                 {peer_rows} rows vs this slice's {} x {}); refusing the anti-entropy merge",
                index.full_bands(),
                index.config().lsh.rows_per_band
            )));
        }
        // Generation layout: servers that predate the field hold exactly
        // one generation; a peer that rotated further grows this replica
        // to its layout before the per-generation walk.
        let peer_gens = stats
            .get("generations")
            .and_then(|v| v.as_u64())
            .map(|n| n.max(1) as usize)
            .unwrap_or(1);
        index.ensure_generations(peer_gens);
        let mut merged = 0u64;
        let mut transport_failed = false;
        'peer: for gen in 0..peer_gens {
            for band in index.band_range() {
                let reply = match client.pull_band(band, gen) {
                    Ok(r) => r,
                    Err(e) => {
                        last_err =
                            format!("sync peer {addr}: pull_bands({band}, gen {gen}) failed: {e}");
                        crate::log_warn!("{last_err}");
                        transport_failed = true;
                        break 'peer;
                    }
                };
                let Some(words_json) = reply.get("words") else {
                    return Err(invalid_input(format!(
                        "sync peer {addr}: pull_bands({band}, gen {gen}) reply carries no 'words'"
                    )));
                };
                let words = super::proto::words_from_json(words_json, expect_words)
                    .map_err(|e| invalid_input(format!("sync peer {addr}: band {band}: {e}")))?;
                let inserted = reply.get("inserted").and_then(|v| v.as_u64()).unwrap_or(0);
                index
                    .merge_band_words(gen, band, &words, inserted)
                    .map_err(|e| invalid_input(format!("sync peer {addr}: {e}")))?;
                merged = merged.saturating_add(inserted);
                if crash_after_docs > 0 && merged >= crash_after_docs {
                    // Deterministic mid-merge death: some owned bands have
                    // merged, some have not — exactly the torn state the
                    // idempotence property must survive.
                    crate::log_warn!(
                        "LSHBLOOM_REPLICA_CRASH_AFTER_DOCS={crash_after_docs} reached \
                         ({merged} inserts merged); dying mid-merge"
                    );
                    std::process::exit(42);
                }
            }
        }
        if transport_failed {
            continue;
        }
        // Counter convergence: bits are already merged; adopt the peer's
        // view of how many documents produced them.
        if let Some(n) = stats.get("inserted").and_then(|v| v.as_u64()) {
            index.adopt_inserted(n);
        }
        crate::log_info!(
            "anti-entropy merge from {addr} complete ({merged} inserts folded across \
             bands {:?}, {peer_gens} generation(s))",
            index.band_range()
        );
        return Ok(());
    }
    Err(invalid_input(format!(
        "--sync-from: no peer completed the anti-entropy merge (last: {last_err})"
    )))
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    // The bounded-read / overflow / shutdown-polling loop lives in
    // `proto::serve_connection`, shared with the router listener. The
    // server never asks to close after a reply (`false`).
    super::proto::serve_connection(stream, &shared.shutdown, shared.max_line_bytes, |line| {
        (handle_request(line, &shared), false)
    });
}

/// The dedup ops whose latency feeds the `server.request.seconds`
/// histograms. Control ops (`stats`, `metrics`, `shutdown`) are
/// excluded so the sample count equals the dedup requests served —
/// scraping the endpoint must not inflate the histogram it reads.
fn is_dedup_op(op: &str) -> bool {
    matches!(
        op,
        "check" | "query" | "check_batch" | "check_bands" | "check_bands_batch"
    )
}

fn handle_request(line: &str, shared: &Shared) -> Value {
    let reg = crate::obs::global();
    let inflight = reg.gauge("server.inflight_requests");
    inflight.add(1.0);
    let start = std::time::Instant::now();
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            inflight.add(-1.0);
            reg.counter("server.errors.total").inc();
            return error_response(format!("bad request json: {e}"));
        }
    };
    let op = req.get("op").and_then(|v| v.as_str()).map(str::to_string);
    // The whole request runs under a trace root: adopted when the peer
    // sent a context (a router hop, a traced client), minted fresh
    // otherwise. A garbled `trace` field parses to `None` and the
    // request proceeds untraced — tracing never rejects traffic.
    let ctx = super::proto::trace_from_request(&req);
    let label = op.as_deref().unwrap_or("unknown");
    let root = match ctx {
        Some(c) => crate::obs::trace::adopt_root(c, label, shared.trace),
        None => crate::obs::trace::start_root(label, shared.trace),
    };
    let mut resp = dispatch_request(&req, shared);
    if let Some(op) = op.as_deref().filter(|&op| is_dedup_op(op)) {
        let elapsed = start.elapsed();
        reg.histogram("server.request.seconds").record_duration(elapsed);
        reg.histogram(&format!("server.request.seconds{{op=\"{op}\"}}"))
            .record_duration(elapsed);
        reg.counter("server.requests.total").inc();
    }
    if resp.get("error").is_some() {
        reg.counter("server.errors.total").inc();
        // Error traces always record, whatever the sampling verdict.
        crate::obs::trace::force_record();
    }
    if ctx.is_some() {
        // The caller is traced: report this hop's span ID and the
        // server-side duration so it can split wire time from work.
        if let Some(local) = crate::obs::trace::current_context() {
            if let Value::Obj(map) = &mut resp {
                map.insert(
                    "trace".to_string(),
                    super::proto::trace_reply(local.span_id, start.elapsed().as_nanos() as u64),
                );
            }
        }
    }
    drop(root);
    inflight.add(-1.0);
    resp
}

fn dispatch_request(req: &Value, shared: &Shared) -> Value {
    match req.get("op").and_then(|v| v.as_str()) {
        Some("check") | Some("query") => {
            let insert = req.get("op").and_then(|v| v.as_str()) == Some("check");
            let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
                return error_response("missing 'text'");
            };
            match shared.backend.decide(text, insert) {
                Ok(duplicate) if insert => {
                    let id = shared.stats.docs.fetch_add(1, Ordering::SeqCst);
                    if duplicate {
                        shared.stats.duplicates.fetch_add(1, Ordering::SeqCst);
                    }
                    obj(vec![
                        ("duplicate", Value::Bool(duplicate)),
                        ("id", Value::u64(id)),
                    ])
                }
                Ok(duplicate) => obj(vec![("duplicate", Value::Bool(duplicate))]),
                Err(e) => error_response(e),
            }
        }
        Some("check_batch") => {
            let Some(texts_json) = req.get("texts").and_then(|v| v.as_arr()) else {
                return error_response("missing 'texts' array");
            };
            let mut texts = Vec::with_capacity(texts_json.len());
            for (i, t) in texts_json.iter().enumerate() {
                let Some(s) = t.as_str() else {
                    return error_response(format!("texts[{i}] is not a string"));
                };
                texts.push(s);
            }
            let verdicts = match shared.backend.decide_batch(&texts) {
                Ok(v) => v,
                Err(e) => return error_response(e),
            };
            let first_id = shared.stats.docs.fetch_add(texts.len() as u64, Ordering::SeqCst);
            let dups = verdicts.iter().filter(|&&d| d).count() as u64;
            shared.stats.duplicates.fetch_add(dups, Ordering::SeqCst);
            obj(vec![
                (
                    "duplicates",
                    Value::Arr(verdicts.into_iter().map(Value::Bool).collect()),
                ),
                (
                    "ids",
                    Value::Arr(
                        (0..texts.len() as u64).map(|i| Value::u64(first_id + i)).collect(),
                    ),
                ),
            ])
        }
        Some("check_bands") => {
            let Some(bands_json) = req.get("bands") else {
                return error_response("missing 'bands' array");
            };
            let bands = match bands_from_json(bands_json, shared.backend.num_bands()) {
                Ok(b) => b,
                Err(e) => return error_response(format!("check_bands: {e}")),
            };
            let insert = req.get("insert").and_then(|v| v.as_bool()).unwrap_or(true);
            match shared.backend.decide_bands(&bands, insert) {
                Ok(duplicate) if insert => {
                    let id = shared.stats.docs.fetch_add(1, Ordering::SeqCst);
                    if duplicate {
                        shared.stats.duplicates.fetch_add(1, Ordering::SeqCst);
                    }
                    obj(vec![
                        ("duplicate", Value::Bool(duplicate)),
                        ("id", Value::u64(id)),
                    ])
                }
                Ok(duplicate) => obj(vec![("duplicate", Value::Bool(duplicate))]),
                Err(e) => error_response(e),
            }
        }
        Some("check_bands_batch") => {
            let Some(batch_json) = req.get("bands_batch").and_then(|v| v.as_arr()) else {
                return error_response("missing 'bands_batch' array");
            };
            let expect = shared.backend.num_bands();
            let mut batch = Vec::with_capacity(batch_json.len());
            for (i, doc) in batch_json.iter().enumerate() {
                match bands_from_json(doc, expect) {
                    Ok(b) => batch.push(b),
                    Err(e) => return error_response(format!("check_bands_batch[{i}]: {e}")),
                }
            }
            let pre = match shared.backend.probe_insert_bands(&batch) {
                Ok(p) => p,
                Err(e) => return error_response(e),
            };
            shared.stats.docs.fetch_add(batch.len() as u64, Ordering::SeqCst);
            let dups = pre.iter().filter(|&&d| d).count() as u64;
            shared.stats.duplicates.fetch_add(dups, Ordering::SeqCst);
            obj(vec![(
                "pre_duplicates",
                Value::Arr(pre.into_iter().map(Value::Bool).collect()),
            )])
        }
        Some("stats") => {
            let (slice, count) = shared.backend.slice_layout();
            let mut fields = vec![
                ("docs", Value::u64(shared.stats.docs.load(Ordering::SeqCst))),
                (
                    "duplicates",
                    Value::u64(shared.stats.duplicates.load(Ordering::SeqCst)),
                ),
                ("disk_bytes", Value::u64(shared.current_disk_bytes())),
                ("shard_workers", Value::u64(shared.shard_workers)),
                ("num_bands", Value::u64(shared.backend.num_bands() as u64)),
                ("rows_per_band", Value::u64(shared.backend.rows_per_band() as u64)),
                ("band_ops", Value::Bool(shared.backend.supports_band_ops())),
                ("slice_index", Value::u64(slice as u64)),
                ("slice_count", Value::u64(count as u64)),
                ("uptime_seconds", Value::num(crate::obs::uptime_seconds())),
                ("version", Value::str(env!("CARGO_PKG_VERSION"))),
            ];
            // Index insert counter (absent on the classic backend): the
            // router's replica handshake compares this across replicas
            // of one slice to catch a diverged restartee at bind.
            if let Some(n) = shared.backend.inserted() {
                fields.push(("inserted", Value::u64(n)));
            }
            // Generation layout (absent on the classic backend): the
            // other half of the replica handshake — and what a syncing
            // replica reads to grow to its peer's rotation history.
            if let Some(n) = shared.backend.generations() {
                fields.push(("generations", Value::u64(n)));
            }
            obj(fields)
        }
        Some("pull_bands") => {
            // Anti-entropy read: one owned band's filter words — of one
            // generation, oldest first; `gen` defaults to 0 so
            // pre-generational pullers keep working — exact u64 tokens,
            // plus the geometry echo the puller validates before
            // OR-merging. Served by slice backends only — they are the
            // replicated tier; full backends publish checkpoints instead.
            let Some(band) = req.get("band").and_then(|v| v.as_u64()) else {
                return error_response("pull_bands: missing 'band' (global band index)");
            };
            let gen = req.get("gen").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
            let IndexBackend::Slice { index, .. } = &shared.backend else {
                return error_response(
                    "pull_bands requires a band-slice backend (--slice-index); \
                     full indexes publish checkpoints instead",
                );
            };
            let band = band as usize;
            if gen >= index.num_generations() {
                return error_response(format!(
                    "pull_bands: generation {gen} is beyond this slice's {} generation(s)",
                    index.num_generations()
                ));
            }
            match (index.band_words(gen, band), index.band_inserted(gen, band)) {
                (Some(words), Some(inserted)) => obj(vec![
                    ("band", Value::u64(band as u64)),
                    ("gen", Value::u64(gen as u64)),
                    ("generations", Value::u64(index.num_generations() as u64)),
                    ("num_bands", Value::u64(index.full_bands() as u64)),
                    (
                        "rows_per_band",
                        Value::u64(index.config().lsh.rows_per_band as u64),
                    ),
                    ("inserted", Value::u64(inserted)),
                    ("words", super::proto::words_to_json(&words)),
                ]),
                _ => {
                    let range = index.band_range();
                    error_response(format!(
                        "pull_bands: band {band} is outside this slice's range \
                         [{}, {})",
                        range.start, range.end
                    ))
                }
            }
        }
        Some("metrics") => {
            // Same freshness contract as a scrape: re-sample the filter
            // gauges, then dump the whole registry.
            shared.refresh_gauges();
            crate::obs::global().to_json()
        }
        Some("trace_dump") => super::proto::trace_dump_response(req),
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            obj(vec![("ok", Value::Bool(true))])
        }
        Some(other) => error_response(format!("unknown op '{other}'")),
        None => error_response("missing 'op'"),
    }
}
