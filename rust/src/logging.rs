//! Minimal leveled stderr logger.
//!
//! No `log`/`env_logger` facade offline; this is a tiny global logger with
//! levels controlled by `LSHBLOOM_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Timestamps are seconds since
//! process start to keep output deterministic-ish and cheap.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log verbosity levels, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global level programmatically.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `LSHBLOOM_LOG` environment variable (call once).
pub fn init_from_env() {
    start();
    if let Ok(v) = std::env::var("LSHBLOOM_LOG") {
        let lv = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        set_level(lv);
    }
}

/// Whether a message at `level` would be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a log line (used by the macros; prefer those).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

/// Emit the slow-request line used by the tracing layer: one WARN line
/// with the total wall time and the per-hop breakdown inline, e.g.
///
/// ```text
/// [    1.042s WARN ] slow-request op=check_batch total=112.4ms trace=4f…e2 hops=[hop 10.0.0.1:9001=54.1ms(srv 53.0ms), hop 10.0.0.2:9001=58.0ms]
/// ```
///
/// `hops` is `(label, client_ms, server_ms)`; a `server_ms` of `0.0`
/// (no far-side timing reported) omits the `(srv …)` suffix.
pub fn slow_request(op: &str, total_ms: f64, trace_id: &str, hops: &[(String, f64, f64)]) {
    if !enabled(Level::Warn) {
        return;
    }
    let mut breakdown = String::new();
    for (i, (label, client_ms, server_ms)) in hops.iter().enumerate() {
        if i > 0 {
            breakdown.push_str(", ");
        }
        breakdown.push_str(&format!("{label}={client_ms:.1}ms"));
        if *server_ms > 0.0 {
            breakdown.push_str(&format!("(srv {server_ms:.1}ms)"));
        }
    }
    let line = format!(
        "slow-request op={op} total={total_ms:.1}ms trace={trace_id} hops=[{breakdown}]"
    );
    emit(Level::Warn, format_args!("{line}"));
}

/// Log at error level.
#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::logging::emit($crate::logging::Level::Error, format_args!($($t)*)) } }
/// Log at warn level.
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::logging::emit($crate::logging::Level::Warn, format_args!($($t)*)) } }
/// Log at info level.
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::logging::emit($crate::logging::Level::Info, format_args!($($t)*)) } }
/// Log at debug level.
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::logging::emit($crate::logging::Level::Debug, format_args!($($t)*)) } }
/// Log at trace level (span timings, per-request detail).
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::logging::emit($crate::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    // One test mutates the global level (tests run concurrently), so
    // all gating assertions live here.
    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        // Trace is gated off at every default-ish level…
        assert!(!enabled(Level::Trace));
        set_level(Level::Debug);
        assert!(!enabled(Level::Trace));
        // …and on only at Trace itself, where the macro emits.
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        crate::log_trace!("trace macro is exported and callable: {}", 42);
        set_level(Level::Info);
        assert!(!enabled(Level::Trace));
    }

    #[test]
    fn slow_request_line_formats_every_hop_shape() {
        // Smoke: hop with and without a server-side timing, plus the
        // empty-hops case, must all format without panicking.
        let hops = vec![
            ("hop 10.0.0.1:9001".to_string(), 54.13, 53.02),
            ("hop 10.0.0.2:9001".to_string(), 58.0, 0.0),
        ];
        slow_request("check_batch", 112.41, "00ab", &hops);
        slow_request("check", 7.5, "00cd", &[]);
    }
}
