//! Optimal LSH band geometry (b, r).
//!
//! Implements the paper's Eqs. (1)–(2) and the datasketch/Zhu-et-al.
//! `_optimal_param` search: enumerate all (b, r) with `b·r ≤ P`, score by
//! `w_fp·FP + w_fn·FN` with the integrals evaluated by midpoint-rectangle
//! integration at dx = 0.001, pick the argmin.
//!
//! `python/compile/lsh_params.py` implements the identical procedure; the
//! AOT manifest pins both sides together (`rust/tests/xla_backend.rs`).

const INTEGRATION_DX: f64 = 0.001;

fn integrate<F: Fn(f64) -> f64>(f: F, a: f64, b: f64) -> f64 {
    let mut area = 0.0;
    let mut x = a;
    while x < b {
        area += f(x + 0.5 * INTEGRATION_DX) * INTEGRATION_DX;
        x += INTEGRATION_DX;
    }
    area
}

/// Paper Eq. (1): probability mass of false positives below threshold T.
pub fn false_positive_probability(threshold: f64, b: usize, r: usize) -> f64 {
    integrate(
        |t| 1.0 - (1.0 - t.powi(r as i32)).powi(b as i32),
        0.0,
        threshold,
    )
}

/// Paper Eq. (2): probability mass of false negatives above threshold T.
pub fn false_negative_probability(threshold: f64, b: usize, r: usize) -> f64 {
    integrate(
        |t| (1.0 - t.powi(r as i32)).powi(b as i32),
        threshold,
        1.0,
    )
}

/// Resolved LSH band geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LshParams {
    /// Number of bands b (= number of Bloom filters in LSHBloom).
    pub num_bands: usize,
    /// Rows per band r.
    pub rows_per_band: usize,
}

impl LshParams {
    /// Signature rows actually consumed (`b·r ≤ P`).
    pub fn rows_used(&self) -> usize {
        self.num_bands * self.rows_per_band
    }
}

/// Find the (b, r) minimizing `0.5·FP + 0.5·FN` (datasketch defaults).
pub fn optimal_param(threshold: f64, num_perm: usize) -> LshParams {
    optimal_param_weighted(threshold, num_perm, 0.5, 0.5)
}

/// Weighted variant (`fp_weight + fn_weight` need not sum to 1).
pub fn optimal_param_weighted(
    threshold: f64,
    num_perm: usize,
    fp_weight: f64,
    fn_weight: f64,
) -> LshParams {
    assert!(num_perm >= 1);
    let mut best = (f64::INFINITY, LshParams { num_bands: 1, rows_per_band: 1 });
    for b in 1..=num_perm {
        let max_r = num_perm / b;
        for r in 1..=max_r {
            let err = fp_weight * false_positive_probability(threshold, b, r)
                + fn_weight * false_negative_probability(threshold, b, r);
            if err < best.0 {
                best = (err, LshParams { num_bands: b, rows_per_band: r });
            }
        }
    }
    best.1
}

/// The LSH S-curve: probability two docs with Jaccard similarity `s`
/// share at least one identical band.
pub fn collision_probability(s: f64, params: LshParams) -> f64 {
    1.0 - (1.0 - s.powi(params.rows_per_band as i32)).powi(params.num_bands as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_t08_p128_gives_9_bands() {
        // §4.5: "a Jaccard similarity threshold T of 0.8, and 128 random
        // permutations ... MinHashLSH creates nine bands".
        let p = optimal_param(0.8, 128);
        assert_eq!(p.num_bands, 9);
        assert_eq!(p.rows_per_band, 13);
    }

    #[test]
    fn main_config_matches_python_manifest() {
        // aot.py lowered T=0.5/P=256 as (42, 6) and test config T=0.5/P=128.
        let p = optimal_param(0.5, 256);
        assert_eq!((p.num_bands, p.rows_per_band), (42, 6));
        let p = optimal_param(0.5, 128);
        assert_eq!((p.num_bands, p.rows_per_band), (25, 5));
    }

    #[test]
    fn geometry_fits_permutations() {
        for &t in &[0.2, 0.4, 0.5, 0.6, 0.8, 1.0f64] {
            for &p in &[32usize, 48, 64, 128, 256] {
                let params = optimal_param(t, p);
                assert!(params.rows_used() <= p, "t={t} p={p}: {params:?}");
                assert!(params.num_bands >= 1 && params.rows_per_band >= 1);
            }
        }
    }

    #[test]
    fn integrals_are_probability_masses() {
        let (b, r) = (9, 13);
        let fp = false_positive_probability(0.8, b, r);
        let fn_ = false_negative_probability(0.8, b, r);
        assert!(fp > 0.0 && fp < 0.8);
        assert!(fn_ > 0.0 && fn_ < 0.2);
    }

    #[test]
    fn fp_monotone_in_bands_fn_antitone() {
        // More bands -> more collisions -> FP up, FN down.
        let t = 0.5;
        let fp1 = false_positive_probability(t, 4, 8);
        let fp2 = false_positive_probability(t, 16, 8);
        assert!(fp2 > fp1);
        let fn1 = false_negative_probability(t, 4, 8);
        let fn2 = false_negative_probability(t, 16, 8);
        assert!(fn2 < fn1);
    }

    #[test]
    fn s_curve_shape() {
        let p = LshParams { num_bands: 9, rows_per_band: 13 };
        assert!(collision_probability(0.1, p) < 0.01);
        assert!(collision_probability(0.95, p) > 0.99);
        // Monotone increasing.
        let mut prev = 0.0;
        for i in 0..=20 {
            let c = collision_probability(i as f64 / 20.0, p);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn higher_fn_weight_prefers_more_bands() {
        let fn_heavy = optimal_param_weighted(0.5, 128, 0.1, 0.9);
        let fp_heavy = optimal_param_weighted(0.5, 128, 0.9, 0.1);
        assert!(fn_heavy.num_bands >= fp_heavy.num_bands);
    }
}
