//! Native MinHash signature computation.
//!
//! A document is shingled (word n-grams over normalized text), each
//! shingle hashed to u64 (SHA-1 low-8), and the signature is the
//! per-permutation minimum over the shingle hashes:
//!
//! * [`PermFamily::Mix64`] — `min_t mix64(t ^ seed_i)`; identical to the
//!   Pallas kernel / XLA artifacts (golden vectors pin this).
//! * [`PermFamily::Datasketch`] — `min_t ((a_i·t + b_i) mod p) & 2^32-1`;
//!   faithful to the paper's datasketch baseline, needs u128 (§4.4.1).
//!
//! The empty document yields a signature of all `u64::MAX` (matching the
//! kernel's padded-row semantics).

use crate::hash::mix64::{self, PERM_MASTER_SEED};
use crate::hash::universal::{self, PermPair};
use crate::hash::token_hash_u64;
use crate::text::{ngram::word_ngrams, tokenize::whitespace_tokens};

/// A document signature: `P` u64 MinHash values.
pub type Signature = Vec<u64>;

/// Which permutation family drives the signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PermFamily {
    /// splitmix64-finalizer family (XLA-identical).
    Mix64,
    /// datasketch-compatible `(a·h+b) mod 2^61-1`, truncated to 32 bits.
    Datasketch,
}

/// Signature generator: holds derived permutation state.
pub struct MinHasher {
    family: PermFamily,
    /// mix64 family: per-permutation seeds.
    seeds: Vec<u64>,
    /// datasketch family: (a, b) pairs.
    pairs: Vec<PermPair>,
    ngram: usize,
}

impl MinHasher {
    /// Build for `num_perms` permutations and word `ngram` shingles.
    pub fn new(family: PermFamily, num_perms: usize, ngram: usize) -> Self {
        assert!(num_perms > 0 && ngram > 0);
        match family {
            PermFamily::Mix64 => Self {
                family,
                seeds: mix64::derive_seeds(PERM_MASTER_SEED, num_perms),
                pairs: Vec::new(),
                ngram,
            },
            PermFamily::Datasketch => Self {
                family,
                seeds: Vec::new(),
                pairs: universal::derive_pairs(PERM_MASTER_SEED, num_perms),
                ngram,
            },
        }
    }

    /// Number of permutations.
    pub fn num_perms(&self) -> usize {
        match self.family {
            PermFamily::Mix64 => self.seeds.len(),
            PermFamily::Datasketch => self.pairs.len(),
        }
    }

    /// Permutation seeds (mix64 family) — the values fed to the XLA
    /// artifact's `seeds` input.
    pub fn seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// N-gram size used for shingling.
    pub fn ngram(&self) -> usize {
        self.ngram
    }

    /// Shingle a normalized document into *unique* token hashes (the
    /// kernel-input representation; also used by the XLA batch
    /// marshaller). MinHash has set semantics, so repeated shingles are
    /// skipped before the SHA-1 — detected with a cheap 64-bit pre-hash
    /// (§Perf: Zipf text repeats heavily; a pre-hash collision merely
    /// drops one shingle, indistinguishable from an ordinary token-hash
    /// collision at the same 2^-64 scale).
    pub fn shingle_hashes(&self, text: &str) -> Vec<u64> {
        use std::collections::HashSet;
        let tokens: Vec<&str> = whitespace_tokens(text).collect();
        let mut seen: HashSet<u64> = HashSet::with_capacity(tokens.len());
        let mut hashes = Vec::with_capacity(tokens.len());
        word_ngrams(&tokens, self.ngram, |sh| {
            if seen.insert(crate::hash::fast_str_hash(sh.as_bytes())) {
                hashes.push(token_hash_u64(sh.as_bytes()));
            }
        });
        hashes
    }

    /// Signature of a pre-hashed shingle multiset.
    ///
    /// Hot path (§Perf): duplicate shingles are removed first (MinHash is
    /// a set operation, and Zipf-distributed text repeats heavily), then
    /// each permutation reduces the unique hashes with four independent
    /// accumulators — no signature-array traffic in the inner loop and a
    /// broken `min` dependency chain. See EXPERIMENTS.md §Perf.
    pub fn signature_of_hashes(&self, hashes: &[u64]) -> Signature {
        // Dedup: sort + dedup beats a hash set at these sizes.
        let mut uniq: Vec<u64> = hashes.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        self.signature_of_unique_hashes(&uniq)
    }

    /// Signature over hashes already known to be unique (or where the
    /// caller accepts multiset semantics — the min is unaffected).
    pub fn signature_of_unique_hashes(&self, uniq: &[u64]) -> Signature {
        #[inline(always)]
        fn reduce<F: Fn(u64) -> u64>(uniq: &[u64], apply: F) -> u64 {
            let mut acc = [u64::MAX; 4];
            let chunks = uniq.chunks_exact(4);
            let rem = chunks.remainder();
            for c in chunks {
                acc[0] = acc[0].min(apply(c[0]));
                acc[1] = acc[1].min(apply(c[1]));
                acc[2] = acc[2].min(apply(c[2]));
                acc[3] = acc[3].min(apply(c[3]));
            }
            let mut m = acc[0].min(acc[1]).min(acc[2].min(acc[3]));
            for &h in rem {
                m = m.min(apply(h));
            }
            m
        }
        match self.family {
            PermFamily::Mix64 => self
                .seeds
                .iter()
                .map(|&seed| reduce(uniq, |h| mix64::perm(h, seed)))
                .collect(),
            PermFamily::Datasketch => self
                .pairs
                .iter()
                .map(|pair| reduce(uniq, |h| pair.apply(h)))
                .collect(),
        }
    }

    /// Full path: normalized text -> signature.
    pub fn signature(&self, text: &str) -> Signature {
        self.signature_of_hashes(&self.shingle_hashes(text))
    }
}

/// Estimate Jaccard similarity from two signatures (fraction of equal
/// rows) — the MinHash estimator (§2.2).
pub fn estimate_jaccard(a: &Signature, b: &Signature) -> f64 {
    assert_eq!(a.len(), b.len());
    let eq = a.iter().zip(b).filter(|(x, y)| x == y).count();
    eq as f64 / a.len() as f64
}

/// Exact Jaccard similarity of two shingle-hash sets (test oracle).
pub fn exact_jaccard(a: &[u64], b: &[u64]) -> f64 {
    use std::collections::HashSet;
    let sa: HashSet<u64> = a.iter().copied().collect();
    let sb: HashSet<u64> = b.iter().copied().collect();
    if sa.is_empty() && sb.is_empty() {
        return 1.0;
    }
    let inter = sa.intersection(&sb).count();
    let union = sa.len() + sb.len() - inter;
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lorem(n: usize, offset: usize) -> String {
        (0..n).map(|i| format!("w{}", i + offset)).collect::<Vec<_>>().join(" ")
    }

    #[test]
    fn identical_docs_identical_signatures() {
        for family in [PermFamily::Mix64, PermFamily::Datasketch] {
            let mh = MinHasher::new(family, 128, 1);
            let a = mh.signature("alpha beta gamma delta");
            let b = mh.signature("alpha beta gamma delta");
            assert_eq!(a, b);
        }
    }

    #[test]
    fn empty_doc_is_all_max() {
        let mh = MinHasher::new(PermFamily::Mix64, 64, 1);
        assert!(mh.signature("").iter().all(|&v| v == u64::MAX));
    }

    #[test]
    fn signature_order_invariant_set_semantics() {
        let mh = MinHasher::new(PermFamily::Mix64, 128, 1);
        // Same token multiset in different orders -> same shingle set (n=1).
        let a = mh.signature("one two three four");
        let b = mh.signature("four three two one");
        assert_eq!(a, b);
    }

    #[test]
    fn estimator_tracks_exact_jaccard() {
        // Construct docs with known overlap; estimator within ~0.1.
        for family in [PermFamily::Mix64, PermFamily::Datasketch] {
            let mh = MinHasher::new(family, 256, 1);
            let a_text = lorem(200, 0);
            let b_text = lorem(200, 100); // words 100..300: Jaccard = 100/300
            let ha = mh.shingle_hashes(&a_text);
            let hb = mh.shingle_hashes(&b_text);
            let exact = exact_jaccard(&ha, &hb);
            let est = estimate_jaccard(
                &mh.signature_of_hashes(&ha),
                &mh.signature_of_hashes(&hb),
            );
            assert!(
                (est - exact).abs() < 0.1,
                "{family:?}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn ngram_size_changes_shingles() {
        let mh1 = MinHasher::new(PermFamily::Mix64, 32, 1);
        let mh2 = MinHasher::new(PermFamily::Mix64, 32, 2);
        let text = "a b c d e";
        assert_eq!(mh1.shingle_hashes(text).len(), 5);
        assert_eq!(mh2.shingle_hashes(text).len(), 4);
        assert_ne!(mh1.signature(text), mh2.signature(text));
    }

    #[test]
    fn datasketch_signatures_are_32bit() {
        let mh = MinHasher::new(PermFamily::Datasketch, 64, 1);
        let sig = mh.signature("some example document text");
        assert!(sig.iter().all(|&v| v <= u32::MAX as u64));
    }

    #[test]
    fn matches_golden_semantics_for_mix64() {
        // Mirror of the python ref oracle on a toy case: one token.
        let mh = MinHasher::new(PermFamily::Mix64, 8, 1);
        let h = token_hash_u64(b"tok");
        let sig = mh.signature_of_hashes(&[h]);
        for (i, &seed) in mh.seeds().iter().enumerate() {
            assert_eq!(sig[i], crate::rng::mix64(h ^ seed));
        }
    }
}
