//! MinHash signatures and LSH band parameters (§2.2–§2.3).
//!
//! * [`params`] — optimal (b, r) selection minimizing the weighted FP/FN
//!   integrals (paper Eqs. 1–2, Zhu et al. procedure); kept in lock-step
//!   with `python/compile/lsh_params.py`.
//! * [`signature`] — native signature computation over shingle sets for
//!   both permutation families (mix64 / datasketch-compatible).

pub mod params;
pub mod signature;

pub use params::{optimal_param, LshParams};
pub use signature::{MinHasher, PermFamily, Signature};
