//! The capacity oracle — one place derives every piece of filter
//! geometry (ROADMAP "capacity autopilot").
//!
//! Historically the sizing math lived in three places that could drift:
//! [`crate::minhash::optimal_param`] picked the band layout,
//! [`crate::bloom::BloomParams::for_capacity`] sized each filter, and
//! `bloom/scalable.rs` carried its own private stage-growth rules. A
//! [`Plan`] collapses them behind three operator inputs — target Jaccard
//! threshold, expected document count, and a total false-positive budget
//! (`dedup/serve --threshold T --expect-docs N --fp-budget p`, config
//! keys `capacity.*`) — and every index construction path funnels
//! through [`filter_geometry`], so engine, persist, and serving tiers
//! always agree on layout.
//!
//! The plan also fixes *when to grow*: at the planned capacity a filter
//! sits at ~50% fill (the optimum the §4.5 sizing rule lands on), so the
//! default rotation watermark of 0.5 means "rotate exactly when the open
//! generation reaches the capacity it was sized for".

use crate::bloom::BloomParams;
use crate::config::PipelineConfig;
use crate::error::{Error, Result};
use crate::index::LshBloomConfig;
use crate::json::{self, Value};
use crate::minhash::{optimal_param, LshParams};

/// Error tightening ratio between successive scalable-filter stages.
pub const STAGE_TIGHTENING: f64 = 0.5;
/// Capacity growth factor between successive scalable-filter stages.
pub const STAGE_GROWTH: u64 = 2;

/// Per-band Bloom geometry for a resolved band count: the §4.3 budget
/// split `p = 1-(1-p_eff)^(1/b)` followed by the §4.5 sizing rule.
/// This is the single source of truth for (bits, hashes) — the classic
/// index, the concurrent engine, checkpoints, and the serving handshake
/// all call it (directly or via `LshBloomIndex::filter_params`).
pub fn filter_geometry(num_bands: usize, fp_budget: f64, expected_docs: u64) -> BloomParams {
    let p = BloomParams::per_filter_rate(fp_budget, num_bands);
    BloomParams::for_capacity(expected_docs.max(1), p)
}

/// FP budget share of scalable stage `i`: `p_total·(1-r)·r^i`, chosen so
/// the stage budgets sum to `p_total` over an unbounded chain.
pub fn scalable_stage_rate(p_total: f64, stage: usize) -> f64 {
    p_total * (1.0 - STAGE_TIGHTENING) * STAGE_TIGHTENING.powi(stage as i32)
}

/// Geometry of scalable stage `i`: capacity `initial·G^i` at that
/// stage's share of the total budget. `bloom::scalable` re-derives its
/// chain through here instead of carrying its own copy of the math.
pub fn scalable_stage_params(initial_capacity: u64, p_total: f64, stage: usize) -> BloomParams {
    let capacity = initial_capacity * STAGE_GROWTH.pow(stage as u32);
    BloomParams::for_capacity(capacity, scalable_stage_rate(p_total, stage))
}

/// A fully-derived capacity plan: all the geometry the engine, persist,
/// and serving tiers need, derived once from three operator inputs.
#[derive(Clone, Copy, Debug)]
pub struct Plan {
    /// Target Jaccard threshold T.
    pub threshold: f64,
    /// MinHash permutations P.
    pub num_perms: usize,
    /// Planned corpus cardinality n (sizes each generation's filters).
    pub expected_docs: u64,
    /// Index-wide false-positive budget p_eff (§4.3).
    pub fp_budget: f64,
    /// Derived band layout (b, r) from the Eq. (1)–(2) argmin search.
    pub lsh: LshParams,
    /// Per-filter rate `p = 1-(1-p_eff)^(1/b)`.
    pub per_filter_rate: f64,
    /// Per-band Bloom geometry (bits, hashes, capacity).
    pub filter: BloomParams,
}

impl Plan {
    /// Derive a plan from the three operator inputs (plus the MinHash
    /// permutation count the signatures were computed with).
    pub fn derive(
        threshold: f64,
        num_perms: usize,
        expected_docs: u64,
        fp_budget: f64,
    ) -> Result<Self> {
        if !(0.0..=1.0).contains(&threshold) {
            return Err(Error::Config(format!("plan threshold {threshold} not in [0,1]")));
        }
        if num_perms == 0 || num_perms > 4096 {
            return Err(Error::Config(format!("plan num_perms {num_perms} out of range")));
        }
        if expected_docs == 0 {
            return Err(Error::Config("plan expected_docs must be positive".into()));
        }
        if !(fp_budget > 0.0 && fp_budget < 1.0) {
            return Err(Error::Config(format!("plan fp_budget {fp_budget} not in (0,1)")));
        }
        let lsh = optimal_param(threshold, num_perms);
        let per_filter_rate = BloomParams::per_filter_rate(fp_budget, lsh.num_bands);
        let filter = filter_geometry(lsh.num_bands, fp_budget, expected_docs);
        Ok(Self { threshold, num_perms, expected_docs, fp_budget, lsh, per_filter_rate, filter })
    }

    /// Derive the plan a [`PipelineConfig`] implies (`--threshold`,
    /// `--expect-docs`, `--fp-budget` / their `capacity.*` keys).
    pub fn from_config(cfg: &PipelineConfig) -> Result<Self> {
        Self::derive(cfg.threshold, cfg.num_perms, cfg.expected_docs, cfg.p_effective)
    }

    /// The index configuration this plan resolves to.
    pub fn index_config(&self) -> LshBloomConfig {
        LshBloomConfig::new(self.lsh, self.fp_budget, self.expected_docs)
    }

    /// Total backing bytes across all `b` filters of one generation.
    pub fn total_bytes(&self) -> u64 {
        self.filter.bytes() * self.lsh.num_bands as u64
    }

    /// One-line human summary for logs and run headers.
    pub fn describe(&self) -> String {
        format!(
            "T={} P={} -> {} bands x {} rows; n={} at fp_budget={:.1e} -> \
             {} bits x {} hashes per band ({} per generation)",
            self.threshold,
            self.num_perms,
            self.lsh.num_bands,
            self.lsh.rows_per_band,
            self.expected_docs,
            self.fp_budget,
            self.filter.bits,
            self.filter.hashes,
            crate::report::table::bytes(self.total_bytes()),
        )
    }

    /// JSON echo for stats replies and checkpoint manifests.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("threshold", Value::num(self.threshold)),
            ("num_perms", Value::u64(self.num_perms as u64)),
            ("expected_docs", Value::u64(self.expected_docs)),
            ("fp_budget", Value::num(self.fp_budget)),
            ("num_bands", Value::u64(self.lsh.num_bands as u64)),
            ("rows_per_band", Value::u64(self.lsh.rows_per_band as u64)),
            ("filter_bits", Value::u64(self.filter.bits)),
            ("filter_hashes", Value::u64(self.filter.hashes as u64)),
            ("total_bytes", Value::u64(self.total_bytes())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_matches_band_and_filter_oracles() {
        // §4.5 worked example: T=0.8, 128 perms -> 9 bands x 13 rows.
        let plan = Plan::derive(0.8, 128, 10_000, 1e-8).unwrap();
        assert_eq!((plan.lsh.num_bands, plan.lsh.rows_per_band), (9, 13));
        let oracle = optimal_param(0.8, 128);
        assert_eq!(plan.lsh, oracle);
        // Filter geometry must be exactly what the legacy two-step
        // derivation produced.
        let p = BloomParams::per_filter_rate(1e-8, 9);
        assert_eq!(plan.filter, BloomParams::for_capacity(10_000, p));
        assert!((plan.per_filter_rate - p).abs() < 1e-18);
    }

    #[test]
    fn plan_agrees_with_index_filter_params() {
        let plan = Plan::derive(0.5, 256, 1_000_000, 1e-10).unwrap();
        let via_index = crate::index::LshBloomIndex::filter_params(&plan.index_config());
        assert_eq!(plan.filter, via_index);
    }

    #[test]
    fn scalable_stage_math_matches_legacy_rules() {
        // Stage i: capacity initial·2^i, rate p_total·(1-0.5)·0.5^i.
        for i in 0..6 {
            let rate = scalable_stage_rate(1e-4, i);
            assert!((rate - 1e-4 * 0.5 * 0.5f64.powi(i as i32)).abs() < 1e-20);
            let params = scalable_stage_params(100, 1e-4, i);
            assert_eq!(params.capacity, 100 * 2u64.pow(i as u32));
            assert_eq!(params, BloomParams::for_capacity(params.capacity, rate));
        }
        // The stage budgets telescope to the total.
        let total: f64 = (0..60).map(|i| scalable_stage_rate(1e-3, i)).sum();
        assert!((total - 1e-3).abs() / 1e-3 < 1e-9);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(Plan::derive(1.5, 128, 1000, 1e-8).is_err());
        assert!(Plan::derive(0.5, 0, 1000, 1e-8).is_err());
        assert!(Plan::derive(0.5, 128, 0, 1e-8).is_err());
        assert!(Plan::derive(0.5, 128, 1000, 0.0).is_err());
        assert!(Plan::derive(0.5, 128, 1000, 1.0).is_err());
    }

    #[test]
    fn describe_and_json_echo_the_derived_numbers() {
        let plan = Plan::from_config(&PipelineConfig::default()).unwrap();
        let text = plan.describe();
        assert!(text.contains("bands"), "{text}");
        let j = plan.to_json();
        assert_eq!(j.get("num_bands").and_then(|v| v.as_u64()), Some(plan.lsh.num_bands as u64));
        assert_eq!(j.get("filter_bits").and_then(|v| v.as_u64()), Some(plan.filter.bits));
    }

    #[test]
    fn total_bytes_reproduces_paper_example() {
        // §4.5: 10B docs, p_eff 1e-10, T=0.8/128 perms -> ~590 GB.
        let plan = Plan::derive(0.8, 128, 10_000_000_000, 1e-10).unwrap();
        let gb = plan.total_bytes() as f64 / 1e9;
        assert!((500.0..700.0).contains(&gb), "paper says ~590 GB, got {gb:.1}");
    }
}
