//! Compact JSON serialization.

use super::Value;

/// Serialize a [`Value`] to a compact JSON string.
pub fn write_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(_, raw) => out.push_str(raw),
        Value::Str(s) => write_escaped(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{obj, parse, Value};
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\ny"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        let emitted = write_string(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn escapes_controls() {
        let v = Value::str("a\u{1}b");
        assert_eq!(write_string(&v), "\"a\\u0001b\"");
    }

    #[test]
    fn deterministic_key_order() {
        let v = obj(vec![("zebra", Value::u64(1)), ("apple", Value::u64(2))]);
        assert_eq!(write_string(&v), r#"{"apple":2,"zebra":1}"#);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::str("café 😀");
        let emitted = write_string(&v);
        assert_eq!(parse(&emitted).unwrap().as_str(), Some("café 😀"));
    }
}
