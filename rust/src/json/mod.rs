//! Minimal JSON substrate (no `serde` offline).
//!
//! Supports the full JSON grammar with a DOM-style [`Value`]; used for
//! JSONL corpora, the AOT artifact manifest, golden vectors, and report
//! emission. Numbers are kept as `f64` plus the raw token so u64 hash
//! values round-trip exactly (the AOT side writes them as strings for
//! that reason, but the parser is robust either way).

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::write_string;

use std::collections::BTreeMap;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Numeric value plus the raw source token (exact integer round-trip).
    Num(f64, String),
    Str(String),
    Arr(Vec<Value>),
    /// BTreeMap for deterministic serialization order.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Build a number value from anything numeric.
    pub fn num<T: Into<f64>>(v: T) -> Value {
        let f = v.into();
        Value::Num(f, fmt_f64(f))
    }

    /// Build a number from a u64 without precision loss in the raw token.
    pub fn u64(v: u64) -> Value {
        Value::Num(v as f64, v.to_string())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(f, _) => Some(*f),
            _ => None,
        }
    }

    /// As u64 — prefers the exact raw token (for 64-bit hash values that
    /// exceed f64's 53-bit mantissa), accepting decimal strings too.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(_, raw) => raw.parse().ok(),
            Value::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        write_string(self)
    }
}

/// Format an f64 the way JSON expects (shortest round-trip-ish).
pub(crate) fn fmt_f64(f: f64) -> String {
    if f.fract() == 0.0 && f.abs() < 1e15 {
        format!("{}", f as i64)
    } else {
        let s = format!("{f}");
        s
    }
}

/// Build an object from pairs (helper for report emission).
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_exact_roundtrip() {
        let v = Value::u64(u64::MAX);
        assert_eq!(v.as_u64(), Some(u64::MAX));
        let parsed = parse(&v.to_json()).unwrap();
        assert_eq!(parsed.as_u64(), Some(u64::MAX));
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": 1, "b": "x", "c": [true, null], "d": 2.5}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("c").unwrap().as_arr().unwrap()[0].as_bool(), Some(true));
        assert_eq!(v.get("d").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn string_number_coercion_for_u64() {
        let v = parse(r#"{"h": "18446744073709551615"}"#).unwrap();
        assert_eq!(v.get("h").unwrap().as_u64(), Some(u64::MAX));
    }
}
