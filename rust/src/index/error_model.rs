//! Analytic error model (§4.3, Eqs. 1–4).
//!
//! `FP_lsh`/`FN_lsh` come from the banding integrals; LSHBloom adds the
//! Bloom false-positive overhead `p_eff` and the band-reduction collision
//! term `b/N`:
//!
//! ```text
//! FP_bloom = FP_lsh + (1 - FP_lsh) · (p_eff + b/N)      (Eq. 3)
//! FN_bloom = (1 - (p_eff + b/N)) · FN_lsh               (Eq. 4)
//! ```

use crate::minhash::params::{false_negative_probability, false_positive_probability};
use crate::minhash::LshParams;

/// Closed-form error bounds for a configured LSHBloom index.
#[derive(Clone, Copy, Debug)]
pub struct ErrorModel {
    /// Banding false-positive mass (Eq. 1).
    pub fp_lsh: f64,
    /// Banding false-negative mass (Eq. 2).
    pub fn_lsh: f64,
    /// Index-wide Bloom overhead p_effective.
    pub p_effective: f64,
    /// Band-reduction collision probability b/N (§4.1; N = 2^64 here).
    pub band_collision: f64,
    /// Eq. 3.
    pub fp_bloom: f64,
    /// Eq. 4.
    pub fn_bloom: f64,
}

impl ErrorModel {
    /// Evaluate the model for a threshold, band geometry, and p_eff.
    /// `hash_range_n` is N of §4.1 (2^64 for this implementation's
    /// wrapping band hash; datasketch's 32-bit default would be 2^32).
    pub fn evaluate(
        threshold: f64,
        lsh: LshParams,
        p_effective: f64,
        hash_range_n: f64,
    ) -> Self {
        let fp_lsh = false_positive_probability(threshold, lsh.num_bands, lsh.rows_per_band);
        let fn_lsh = false_negative_probability(threshold, lsh.num_bands, lsh.rows_per_band);
        let band_collision = lsh.num_bands as f64 / hash_range_n;
        let overhead = p_effective + band_collision;
        let fp_bloom = fp_lsh + (1.0 - fp_lsh) * overhead;
        let fn_bloom = (1.0 - overhead) * fn_lsh;
        Self { fp_lsh, fn_lsh, p_effective, band_collision, fp_bloom, fn_bloom }
    }

    /// Default N = 2^64 variant.
    pub fn evaluate_u64(threshold: f64, lsh: LshParams, p_effective: f64) -> Self {
        Self::evaluate(threshold, lsh, p_effective, 2.0f64.powi(64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lsh_9_13() -> LshParams {
        LshParams { num_bands: 9, rows_per_band: 13 }
    }

    #[test]
    fn bloom_overhead_is_marginal_for_small_p_eff() {
        let m = ErrorModel::evaluate_u64(0.8, lsh_9_13(), 1e-10);
        // Eq. 3 reduces to ~FP_lsh when p_eff ≈ 0.
        assert!((m.fp_bloom - m.fp_lsh) < 1e-9);
        // Eq. 4 reduces to ~FN_lsh.
        assert!((m.fn_lsh - m.fn_bloom) / m.fn_lsh < 1e-9);
    }

    #[test]
    fn larger_p_eff_increases_fp_decreases_fn() {
        let small = ErrorModel::evaluate_u64(0.5, lsh_9_13(), 1e-10);
        let large = ErrorModel::evaluate_u64(0.5, lsh_9_13(), 1e-2);
        assert!(large.fp_bloom > small.fp_bloom);
        assert!(large.fn_bloom < small.fn_bloom);
    }

    #[test]
    fn eq3_eq4_closed_forms() {
        let lsh = lsh_9_13();
        let p_eff = 1e-3;
        let n = 2.0f64.powi(32);
        let m = ErrorModel::evaluate(0.6, lsh, p_eff, n);
        let overhead = p_eff + 9.0 / n;
        assert!((m.fp_bloom - (m.fp_lsh + (1.0 - m.fp_lsh) * overhead)).abs() < 1e-15);
        assert!((m.fn_bloom - (1.0 - overhead) * m.fn_lsh).abs() < 1e-15);
    }

    #[test]
    fn fp_bloom_dominates_fp_lsh() {
        // Bloom can only add false positives (Eq. 3) and only remove
        // false negatives (Eq. 4).
        for t in [0.2, 0.5, 0.8] {
            for p_eff in [1e-10, 1e-5, 1e-2] {
                let m = ErrorModel::evaluate_u64(t, lsh_9_13(), p_eff);
                assert!(m.fp_bloom >= m.fp_lsh);
                assert!(m.fn_bloom <= m.fn_lsh);
                assert!(m.fp_bloom <= 1.0 && m.fn_bloom >= 0.0);
            }
        }
    }
}
