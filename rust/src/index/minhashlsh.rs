//! The traditional MinHashLSH index (§2.3) — the baseline LSHBloom
//! replaces.
//!
//! One hash table per band, keyed by the band's content, holding the ids
//! of all documents that produced that key. Faithful to datasketch's
//! `MinHashLSH` hashmap index: the stored keys are the *band slices of
//! the signature* (r hash values each), so storage grows as
//! `O(docs · b · r · 8 bytes)` plus table overhead — the disk blow-up the
//! paper measures in Fig. 7b / Table 2.
//!
//! Disk accounting (`disk_bytes`) counts what persisting the index would
//! take: per entry, the banded key bytes plus a doc id, mirroring the
//! paper's measurement of datasketch's on-disk index.

use super::BandIndex;
use std::collections::HashMap;

/// Hashmap-per-band LSH index storing full band keys.
pub struct MinHashLshIndex {
    /// For each band: key = the r signature values of that band (boxed
    /// slice), value = ids of docs with that key.
    tables: Vec<HashMap<Box<[u64]>, Vec<u64>>>,
    rows_per_band: usize,
    inserted: u64,
}

impl MinHashLshIndex {
    /// New index with `num_bands` tables of `rows_per_band`-value keys.
    pub fn new(num_bands: usize, rows_per_band: usize) -> Self {
        assert!(num_bands > 0 && rows_per_band > 0);
        Self {
            tables: (0..num_bands).map(|_| HashMap::new()).collect(),
            rows_per_band,
            inserted: 0,
        }
    }

    /// Slice a full signature into band keys.
    pub fn band_keys<'a>(&self, signature: &'a [u64]) -> Vec<&'a [u64]> {
        let r = self.rows_per_band;
        (0..self.tables.len()).map(|b| &signature[b * r..(b + 1) * r]).collect()
    }

    /// Query by full signature: true if any band key was seen before.
    pub fn query_signature(&self, signature: &[u64]) -> bool {
        let r = self.rows_per_band;
        self.tables
            .iter()
            .enumerate()
            .any(|(b, t)| t.contains_key(&signature[b * r..(b + 1) * r]))
    }

    /// Query + insert by full signature; returns true if duplicate.
    /// This is the datasketch-faithful path (stores the real band keys).
    pub fn insert_signature_if_new(&mut self, doc_id: u64, signature: &[u64]) -> bool {
        let r = self.rows_per_band;
        let mut dup = false;
        for (b, table) in self.tables.iter_mut().enumerate() {
            let key = &signature[b * r..(b + 1) * r];
            if let Some(ids) = table.get_mut(key) {
                dup = true;
                ids.push(doc_id);
            } else {
                table.insert(key.to_vec().into_boxed_slice(), vec![doc_id]);
            }
        }
        self.inserted += 1;
        dup
    }

    /// Candidate doc ids sharing at least one band with `signature`
    /// (the "candidate pair" retrieval MinHashLSH supports and LSHBloom
    /// intentionally gives up — used by the fidelity harness for
    /// diagnostics).
    pub fn candidates(&self, signature: &[u64]) -> Vec<u64> {
        let r = self.rows_per_band;
        let mut out: Vec<u64> = self
            .tables
            .iter()
            .enumerate()
            .filter_map(|(b, t)| t.get(&signature[b * r..(b + 1) * r]))
            .flatten()
            .copied()
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Rows per band.
    pub fn rows_per_band(&self) -> usize {
        self.rows_per_band
    }
}

impl BandIndex for MinHashLshIndex {
    /// Band-hash interface: keys are the single u64 band hashes (used when
    /// comparing index structures on identical inputs; the fidelity path
    /// uses `*_signature` methods instead).
    fn query(&self, band_hashes: &[u64]) -> bool {
        self.tables
            .iter()
            .zip(band_hashes)
            .any(|(t, h)| t.contains_key(std::slice::from_ref(h)))
    }

    fn insert_if_new(&mut self, band_hashes: &[u64]) -> bool {
        let mut dup = false;
        let doc_id = self.inserted;
        for (table, &h) in self.tables.iter_mut().zip(band_hashes) {
            let key: &[u64] = std::slice::from_ref(&h);
            if let Some(ids) = table.get_mut(key) {
                dup = true;
                ids.push(doc_id);
            } else {
                table.insert(vec![h].into_boxed_slice(), vec![doc_id]);
            }
        }
        self.inserted += 1;
        dup
    }

    fn num_bands(&self) -> usize {
        self.tables.len()
    }

    fn len(&self) -> u64 {
        self.inserted
    }

    fn disk_bytes(&self) -> u64 {
        // Serialized form: per table entry, key bytes + id list bytes
        // (+ 16 bytes of framing per entry, as a pickle/log format would).
        let mut total = 0u64;
        for table in &self.tables {
            for (key, ids) in table {
                total += (key.len() * 8 + ids.len() * 8 + 16) as u64;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn sig(rng: &mut Xoshiro256pp, p: usize) -> Vec<u64> {
        (0..p).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn signature_path_detects_shared_band() {
        let mut idx = MinHashLshIndex::new(3, 2); // needs 6 sig rows
        idx.insert_signature_if_new(0, &[1, 2, 3, 4, 5, 6]);
        // Shares band 1 ([3,4]).
        assert!(idx.query_signature(&[9, 9, 3, 4, 9, 9]));
        assert!(!idx.query_signature(&[9, 9, 9, 9, 9, 9]));
    }

    #[test]
    fn insert_reports_duplicate_and_tracks_candidates() {
        let mut idx = MinHashLshIndex::new(2, 2);
        assert!(!idx.insert_signature_if_new(7, &[1, 2, 3, 4]));
        assert!(idx.insert_signature_if_new(8, &[1, 2, 9, 9]));
        assert_eq!(idx.candidates(&[1, 2, 0, 0]), vec![7, 8]);
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn band_hash_interface_matches_bloom_semantics() {
        let mut idx = MinHashLshIndex::new(4, 13);
        let mut rng = Xoshiro256pp::seeded(5);
        let docs: Vec<Vec<u64>> = (0..200).map(|_| sig(&mut rng, 4)).collect();
        for d in &docs {
            assert!(!idx.insert_if_new(d));
        }
        for d in &docs {
            assert!(idx.query(d));
        }
    }

    #[test]
    fn disk_bytes_grows_linearly_with_docs() {
        let mut idx = MinHashLshIndex::new(9, 13);
        let mut rng = Xoshiro256pp::seeded(6);
        let mut sizes = Vec::new();
        for chunk in 0..4 {
            for _ in 0..250 {
                let s = sig(&mut rng, 9 * 13);
                idx.insert_signature_if_new(chunk, &s);
            }
            sizes.push(idx.disk_bytes());
        }
        let d1 = sizes[1] - sizes[0];
        let d3 = sizes[3] - sizes[2];
        let ratio = d3 as f64 / d1 as f64;
        assert!((0.9..1.1).contains(&ratio), "growth not linear: {sizes:?}");
        // Each doc stores b*(r*8 + 8 + 16) bytes ~ 9*(104+24) = 1152.
        let per_doc = sizes[3] as f64 / 1000.0;
        assert!((1000.0..1400.0).contains(&per_doc), "per-doc bytes {per_doc}");
    }

    #[test]
    fn lshbloom_disk_advantage_materializes() {
        // The headline comparison at small scale: same docs, both indexes.
        use crate::index::lshbloom::{LshBloomConfig, LshBloomIndex};
        use crate::minhash::LshParams;
        let n = 10_000u64;
        let mut lsh = MinHashLshIndex::new(9, 13);
        let mut bloom = LshBloomIndex::new(LshBloomConfig {
            lsh: LshParams { num_bands: 9, rows_per_band: 13 },
            p_effective: 1e-10,
            expected_docs: n,
            blocked: false,
        });
        let mut rng = Xoshiro256pp::seeded(7);
        for i in 0..n {
            let s = sig(&mut rng, 9 * 13);
            lsh.insert_signature_if_new(i, &s);
            let bands: Vec<u64> = (0..9)
                .map(|b| crate::hash::band::band_hash_wrapping(&s[b * 13..(b + 1) * 13]))
                .collect();
            bloom.insert_if_new(&bands);
        }
        let advantage = lsh.disk_bytes() as f64 / bloom.disk_bytes() as f64;
        assert!(advantage > 5.0, "expected large disk advantage, got {advantage:.1}x");
    }
}
