//! Near-duplicate indexes over banded MinHash signatures.
//!
//! * [`LshBloomIndex`] — the paper's contribution (§4): one Bloom filter
//!   per band; insert/query via band sum-hashes on contiguous bit arrays.
//! * [`MinHashLshIndex`] — the traditional baseline (§2.3): one hashmap
//!   per band keyed by band hash, storing document ids (the pointer-heavy
//!   structure LSHBloom replaces).
//! * [`ErrorModel`] — the analytic FP/FN bounds of §4.3 (Eqs. 1–4).
//!
//! Both indexes consume the *same* band-hash representation, so the only
//! difference under benchmark is the index structure itself — the paper's
//! controlled comparison.

pub mod error_model;
pub mod lshbloom;
pub mod minhashlsh;

pub use error_model::ErrorModel;
pub use lshbloom::LshBloomIndex;
pub use minhashlsh::MinHashLshIndex;

/// A near-duplicate index over per-document band hashes.
///
/// `insert_if_new` is the streaming SAMQ operation (§2.1): atomically
/// query-then-insert a document's band hashes, returning whether the
/// document is a duplicate of previously seen content.
pub trait BandIndex {
    /// Query: does any band collide with a previously inserted document?
    fn query(&self, band_hashes: &[u64]) -> bool;

    /// Query + insert in one pass. Returns `true` if the document was a
    /// duplicate (any band collision), `false` if it was new.
    fn insert_if_new(&mut self, band_hashes: &[u64]) -> bool;

    /// Number of bands this index expects.
    fn num_bands(&self) -> usize;

    /// Documents inserted so far.
    fn len(&self) -> u64;

    /// True when no documents have been inserted.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes the index would occupy on disk (the paper's Fig. 7b metric).
    fn disk_bytes(&self) -> u64;
}
