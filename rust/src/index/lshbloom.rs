//! The LSHBloom index (§4): `b` Bloom filters, one per LSH band.
//!
//! Insert: set the k bits of `band_hash[j]` in filter j for every band j.
//! Query: a document is a candidate duplicate iff *any* filter reports
//! all probed bits set (§4.2). Per-filter rate is derived from the
//! index-wide `p_effective` via `p = 1-(1-p_eff)^(1/b)` (§4.3).
//!
//! Persistence: `save_dir`/`load_dir` write one file per filter plus a
//! JSON meta file — or construct with [`LshBloomIndex::new_shm`] to host
//! the bit arrays in `/dev/shm` (§4.4.2).

use super::BandIndex;
use crate::bloom::{BloomFilter, BloomParams};
use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::minhash::LshParams;
use std::path::Path;

/// Configuration for an LSHBloom index.
#[derive(Clone, Copy, Debug)]
pub struct LshBloomConfig {
    /// Band geometry (from [`crate::minhash::optimal_param`]).
    pub lsh: LshParams,
    /// Index-wide effective false-positive bound (§4.3).
    pub p_effective: f64,
    /// Planned corpus cardinality (sizes each filter).
    pub expected_docs: u64,
    /// Use cache-line-blocked filters (§Perf optimization: one cache
    /// miss per band instead of k; ~30% more space, not persistable).
    pub blocked: bool,
}

impl LshBloomConfig {
    /// Classic (persistable) configuration.
    pub fn new(lsh: LshParams, p_effective: f64, expected_docs: u64) -> Self {
        Self { lsh, p_effective, expected_docs, blocked: false }
    }
}

enum BandFilters {
    Classic(Vec<BloomFilter>),
    Blocked(Vec<crate::bloom::BlockedBloomFilter>),
}

/// The per-band Bloom filter index.
pub struct LshBloomIndex {
    filters: BandFilters,
    config: LshBloomConfig,
    inserted: u64,
}

impl LshBloomIndex {
    /// Heap-backed index (classic or blocked filters per `config`).
    pub fn new(config: LshBloomConfig) -> Self {
        let params = Self::filter_params(&config);
        let filters = if config.blocked {
            let p = BloomParams::per_filter_rate(config.p_effective, config.lsh.num_bands);
            BandFilters::Blocked(
                (0..config.lsh.num_bands)
                    .map(|_| {
                        crate::bloom::BlockedBloomFilter::with_capacity(
                            config.expected_docs.max(1),
                            p,
                        )
                    })
                    .collect(),
            )
        } else {
            BandFilters::Classic(
                (0..config.lsh.num_bands).map(|_| BloomFilter::new(params)).collect(),
            )
        };
        Self { filters, config, inserted: 0 }
    }

    /// Index with filters mmap-ed under `dir` (e.g. `/dev/shm/lshbloom`).
    /// Always classic filters (the blocked variant is heap-only).
    pub fn new_shm(config: LshBloomConfig, dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
        let params = Self::filter_params(&config);
        let mut filters = Vec::with_capacity(config.lsh.num_bands);
        for band in 0..config.lsh.num_bands {
            let path = dir.join(format!("band{band:03}.bits"));
            filters.push(BloomFilter::new_shm(params, &path)?);
        }
        Ok(Self { filters: BandFilters::Classic(filters), config, inserted: 0 })
    }

    /// Index wrapping pre-built classic filters (one per band) — the
    /// bridge from a frozen [`crate::engine::ConcurrentLshBloomIndex`]
    /// snapshot to the persistable sequential representation.
    pub(crate) fn from_filters(
        filters: Vec<BloomFilter>,
        config: LshBloomConfig,
        inserted: u64,
    ) -> Self {
        debug_assert_eq!(filters.len(), config.lsh.num_bands);
        Self { filters: BandFilters::Classic(filters), config, inserted }
    }

    /// Per-band Bloom geometry for a config — shared with the concurrent
    /// index so frozen snapshots and bit-OR unions always agree on
    /// filter layout. Delegates to the capacity oracle, the single
    /// source of truth for (bits, hashes).
    pub(crate) fn filter_params(config: &LshBloomConfig) -> BloomParams {
        crate::capacity::filter_geometry(config.lsh.num_bands, config.p_effective, config.expected_docs)
    }

    /// The configuration this index was built with.
    pub fn config(&self) -> LshBloomConfig {
        self.config
    }

    /// Fill ratio of each filter (diagnostics; all should track together).
    pub fn fill_ratios(&self) -> Vec<f64> {
        match &self.filters {
            BandFilters::Classic(fs) => fs.iter().map(|f| f.fill_ratio()).collect(),
            BandFilters::Blocked(fs) => fs.iter().map(|f| f.fill_ratio()).collect(),
        }
    }

    /// Predicted current per-filter FP rate given inserts so far.
    pub fn predicted_filter_fp(&self) -> f64 {
        let params = match &self.filters {
            BandFilters::Classic(fs) => fs.first().map(|f| f.params()),
            BandFilters::Blocked(fs) => fs.first().map(|f| f.params()),
        };
        params.map(|p| p.predicted_fp_rate(self.inserted)).unwrap_or(0.0)
    }

    /// Persist: one `.bloom` file per band + `meta.json`.
    /// Only classic filters persist (blocked is a runtime optimization).
    pub fn save_dir(&self, dir: &Path) -> Result<()> {
        let BandFilters::Classic(filters) = &self.filters else {
            return Err(Error::Config(
                "blocked LSHBloom indexes are not persistable; build with blocked=false".into(),
            ));
        };
        std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
        for (i, f) in filters.iter().enumerate() {
            let path = dir.join(format!("band{i:03}.bloom"));
            let mut w = std::io::BufWriter::new(
                std::fs::File::create(&path).map_err(|e| Error::io(path.display().to_string(), e))?,
            );
            f.save(&mut w)?;
        }
        let meta = json::obj(vec![
            ("num_bands", Value::u64(self.config.lsh.num_bands as u64)),
            ("rows_per_band", Value::u64(self.config.lsh.rows_per_band as u64)),
            ("p_effective", Value::num(self.config.p_effective)),
            ("expected_docs", Value::u64(self.config.expected_docs)),
            ("inserted", Value::u64(self.inserted)),
        ]);
        std::fs::write(dir.join("meta.json"), meta.to_json())
            .map_err(|e| Error::io(dir.display().to_string(), e))?;
        Ok(())
    }

    /// Load a persisted index.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .map_err(|e| Error::io(meta_path.display().to_string(), e))?;
        let meta = json::parse(&meta_text)
            .map_err(|e| Error::parse("lshbloom meta.json", e.to_string()))?;
        let field = |k: &str| {
            meta.get(k)
                .ok_or_else(|| Error::Format(format!("meta.json missing '{k}'")))
        };
        let num_bands = field("num_bands")?.as_usize().unwrap_or(0);
        let rows_per_band = field("rows_per_band")?.as_usize().unwrap_or(0);
        let p_effective = field("p_effective")?.as_f64().unwrap_or(0.0);
        let expected_docs = field("expected_docs")?.as_u64().unwrap_or(0);
        let inserted = field("inserted")?.as_u64().unwrap_or(0);
        if num_bands == 0 || rows_per_band == 0 {
            return Err(Error::Format("meta.json has zero band geometry".into()));
        }
        let mut filters = Vec::with_capacity(num_bands);
        for i in 0..num_bands {
            let path = dir.join(format!("band{i:03}.bloom"));
            let mut r = std::io::BufReader::new(
                std::fs::File::open(&path).map_err(|e| Error::io(path.display().to_string(), e))?,
            );
            filters.push(BloomFilter::load(&mut r)?);
        }
        Ok(Self {
            filters: BandFilters::Classic(filters),
            config: LshBloomConfig {
                lsh: LshParams { num_bands, rows_per_band },
                p_effective,
                expected_docs,
                blocked: false,
            },
            inserted,
        })
    }
}

impl BandIndex for LshBloomIndex {
    fn query(&self, band_hashes: &[u64]) -> bool {
        debug_assert_eq!(band_hashes.len(), self.num_bands());
        match &self.filters {
            BandFilters::Classic(fs) => fs.iter().zip(band_hashes).any(|(f, &h)| f.contains(h)),
            BandFilters::Blocked(fs) => fs.iter().zip(band_hashes).any(|(f, &h)| f.contains(h)),
        }
    }

    fn insert_if_new(&mut self, band_hashes: &[u64]) -> bool {
        debug_assert_eq!(band_hashes.len(), self.num_bands());
        // Single pass: insert() reports whether all bits were already
        // set, so query+insert touches each cache line once.
        let mut dup = false;
        match &mut self.filters {
            BandFilters::Classic(fs) => {
                for (f, &h) in fs.iter_mut().zip(band_hashes) {
                    dup |= f.insert(h);
                }
            }
            BandFilters::Blocked(fs) => {
                for (f, &h) in fs.iter_mut().zip(band_hashes) {
                    dup |= f.insert(h);
                }
            }
        }
        self.inserted += 1;
        dup
    }

    fn num_bands(&self) -> usize {
        match &self.filters {
            BandFilters::Classic(fs) => fs.len(),
            BandFilters::Blocked(fs) => fs.len(),
        }
    }

    fn len(&self) -> u64 {
        self.inserted
    }

    fn disk_bytes(&self) -> u64 {
        match &self.filters {
            BandFilters::Classic(fs) => fs.iter().map(|f| f.size_bytes()).sum(),
            BandFilters::Blocked(fs) => fs.iter().map(|f| f.size_bytes()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn cfg(bands: usize, rows: usize, n: u64) -> LshBloomConfig {
        LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: rows },
            p_effective: 1e-8,
            expected_docs: n,
            blocked: false,
        }
    }

    fn random_bands(rng: &mut Xoshiro256pp, b: usize) -> Vec<u64> {
        (0..b).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn inserted_docs_are_reported_duplicate() {
        let mut idx = LshBloomIndex::new(cfg(9, 13, 10_000));
        let mut rng = Xoshiro256pp::seeded(1);
        let docs: Vec<Vec<u64>> = (0..1000).map(|_| random_bands(&mut rng, 9)).collect();
        for d in &docs {
            assert!(!idx.insert_if_new(d), "fresh doc flagged duplicate");
        }
        for d in &docs {
            assert!(idx.query(d), "no false negatives allowed");
        }
        assert_eq!(idx.len(), 1000);
    }

    #[test]
    fn single_band_match_is_duplicate() {
        let mut idx = LshBloomIndex::new(cfg(4, 2, 1000));
        idx.insert_if_new(&[1, 2, 3, 4]);
        // Shares only band 2's hash.
        assert!(idx.query(&[9, 9, 3, 9]));
        // Shares nothing.
        assert!(!idx.query(&[9, 9, 9, 9]));
    }

    #[test]
    fn fp_rate_bounded_empirically() {
        let mut idx = LshBloomIndex::new(LshBloomConfig {
            lsh: LshParams { num_bands: 9, rows_per_band: 13 },
            p_effective: 1e-4,
            expected_docs: 20_000,
            blocked: false,
        });
        let mut rng = Xoshiro256pp::seeded(2);
        for _ in 0..20_000 {
            idx.insert_if_new(&random_bands(&mut rng, 9));
        }
        let mut fp = 0u64;
        let trials = 100_000;
        for _ in 0..trials {
            if idx.query(&random_bands(&mut rng, 9)) {
                fp += 1;
            }
        }
        let observed = fp as f64 / trials as f64;
        assert!(observed < 1e-4 * 5.0, "observed {observed} >> p_effective");
    }

    #[test]
    fn disk_bytes_matches_formula() {
        let config = cfg(9, 13, 1_000_000);
        let idx = LshBloomIndex::new(config);
        let p = BloomParams::per_filter_rate(config.p_effective, 9);
        let per = BloomParams::for_capacity(1_000_000, p);
        // Word-rounding slack only.
        let expect = per.bytes() * 9;
        let got = idx.disk_bytes();
        assert!((got as i64 - expect as i64).unsigned_abs() <= 9 * 8, "{got} vs {expect}");
    }

    #[test]
    fn save_load_roundtrip_preserves_behaviour() {
        let dir = std::env::temp_dir().join(format!("lshbloom-idx-{}", std::process::id()));
        let mut idx = LshBloomIndex::new(cfg(5, 3, 5000));
        let mut rng = Xoshiro256pp::seeded(3);
        let docs: Vec<Vec<u64>> = (0..500).map(|_| random_bands(&mut rng, 5)).collect();
        for d in &docs {
            idx.insert_if_new(d);
        }
        idx.save_dir(&dir).unwrap();
        let loaded = LshBloomIndex::load_dir(&dir).unwrap();
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.disk_bytes(), idx.disk_bytes());
        for d in &docs {
            assert!(loaded.query(d));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_missing_dir_fails_cleanly() {
        assert!(LshBloomIndex::load_dir(Path::new("/nonexistent-xyz")).is_err());
    }

    #[test]
    fn blocked_index_same_semantics_no_false_negatives() {
        let mut config = cfg(9, 13, 10_000);
        config.blocked = true;
        let mut idx = LshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(8);
        let docs: Vec<Vec<u64>> = (0..2000).map(|_| random_bands(&mut rng, 9)).collect();
        for d in &docs {
            assert!(!idx.insert_if_new(d));
        }
        for d in &docs {
            assert!(idx.query(d));
        }
        // Blocked indexes refuse persistence with a clear error.
        let dir = std::env::temp_dir().join("lshbloom-blocked-nope");
        let err = idx.save_dir(&dir).unwrap_err();
        assert!(err.to_string().contains("blocked"));
    }

    #[test]
    fn blocked_fp_rate_still_bounded() {
        let mut config = cfg(9, 13, 20_000);
        config.p_effective = 1e-4;
        config.blocked = true;
        let mut idx = LshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(9);
        for _ in 0..20_000 {
            idx.insert_if_new(&random_bands(&mut rng, 9));
        }
        let trials = 100_000;
        let mut fp = 0u64;
        for _ in 0..trials {
            fp += idx.query(&random_bands(&mut rng, 9)) as u64;
        }
        let observed = fp as f64 / trials as f64;
        assert!(observed < 1e-4 * 10.0, "blocked FP {observed} above bound");
    }

    #[test]
    fn shm_index_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lshbloom-shm-idx-{}", std::process::id()));
        let mut idx = LshBloomIndex::new_shm(cfg(3, 4, 1000), &dir).unwrap();
        let mut rng = Xoshiro256pp::seeded(4);
        let docs: Vec<Vec<u64>> = (0..100).map(|_| random_bands(&mut rng, 3)).collect();
        for d in &docs {
            idx.insert_if_new(d);
        }
        for d in &docs {
            assert!(idx.query(d));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
