//! Figure 5: precision / recall / F1 of all six techniques (Table-1 best
//! settings) across duplication rates 10%–90% on the testing corpora.
//!
//! `cargo bench --bench fig5_fidelity`

use lshbloom::eval::experiments::{fig5_fidelity, Scale};
use lshbloom::report::{line_plot, CsvWriter, Series};
use std::collections::BTreeMap;
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let rates = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];
    let results = fig5_fidelity(scale, &rates);

    let mut csv = CsvWriter::create(
        Path::new("reports/fig5_fidelity.csv"),
        &["dup_rate", "method", "precision", "recall", "f1", "wall_secs", "disk_bytes"],
    )
    .expect("csv");
    // method -> metric -> series points
    let mut precision: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut recall: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut f1: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    for (rate, evals) in &results {
        for r in evals {
            precision.entry(r.method.clone()).or_default().push((*rate, r.confusion.precision()));
            recall.entry(r.method.clone()).or_default().push((*rate, r.confusion.recall()));
            f1.entry(r.method.clone()).or_default().push((*rate, r.confusion.f1()));
            csv.row_disp(&[
                rate.to_string(),
                r.method.clone(),
                format!("{:.4}", r.confusion.precision()),
                format!("{:.4}", r.confusion.recall()),
                format!("{:.4}", r.confusion.f1()),
                format!("{:.3}", r.wall_secs),
                r.disk_bytes.to_string(),
            ])
            .unwrap();
        }
    }
    csv.finish().unwrap();

    for (name, map) in [("precision", &precision), ("recall", &recall), ("F1", &f1)] {
        let series: Vec<Series> = map
            .iter()
            .map(|(m, pts)| Series::new(m.clone(), pts.clone()))
            .collect();
        println!(
            "{}",
            line_plot(&format!("Fig 5 — {name} vs duplication rate"), "dup rate", name, &series)
        );
    }
    println!(
        "(paper: MinHashLSH/LSHBloom near-identical and best F1 except >60% dup where \
         DCLM/Dolma-Ngram edge ahead; paragraph methods lag in recall)"
    );
}
