//! Figure 7: wall-clock time (a) and disk usage (b) while deduplicating
//! increasing subsets of the peS2o-sim corpus — the 12×/18× headline.
//!
//! `cargo bench --bench fig7_scaling`

use lshbloom::eval::experiments::{fig7_scaling, Scale};
use lshbloom::report::table::{bytes, f, Table};
use lshbloom::report::{line_plot, CsvWriter, Series};
use std::collections::BTreeMap;
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let fractions = [0.01, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0];
    let pts = fig7_scaling(scale, &fractions);

    let mut csv = CsvWriter::create(
        Path::new("reports/fig7_scaling.csv"),
        &["method", "docs", "wall_secs", "disk_bytes", "duplicates"],
    )
    .expect("csv");
    let mut t = Table::new("Fig 7 — scaling", &["method", "docs", "wall (s)", "disk"]);
    let mut wall: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut disk: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    for p in &pts {
        t.row_disp(&[p.method.clone(), p.docs.to_string(), f(p.wall_secs, 2), bytes(p.disk_bytes)]);
        wall.entry(p.method.clone()).or_default().push((p.docs as f64, p.wall_secs));
        disk.entry(p.method.clone()).or_default().push((p.docs as f64, p.disk_bytes as f64 / 1e6));
        csv.row_disp(&[
            p.method.clone(),
            p.docs.to_string(),
            format!("{:.3}", p.wall_secs),
            p.disk_bytes.to_string(),
            p.duplicates.to_string(),
        ])
        .unwrap();
    }
    csv.finish().unwrap();
    t.print();

    let to_series = |m: &BTreeMap<String, Vec<(f64, f64)>>| -> Vec<Series> {
        m.iter().map(|(k, v)| Series::new(k.clone(), v.clone())).collect()
    };
    println!("{}", line_plot("Fig 7a — wall clock vs docs", "docs", "seconds", &to_series(&wall)));
    println!("{}", line_plot("Fig 7b — disk vs docs", "docs", "MB", &to_series(&disk)));

    // Headline ratios at the largest shared size.
    let max_docs = pts.iter().map(|p| p.docs).max().unwrap();
    let at = |m: &str| pts.iter().find(|p| p.method == m && p.docs == max_docs);
    if let (Some(mlsh), Some(lshb)) = (at("minhashlsh"), at("lshbloom")) {
        println!(
            "headline (rust-normalized) at {} docs: {:.1}x wall, {:.1}x disk",
            max_docs,
            mlsh.wall_secs / lshb.wall_secs,
            mlsh.disk_bytes as f64 / lshb.disk_bytes as f64
        );
    }
    let pysim_max = pts.iter().filter(|p| p.method == "minhashlsh-pysim").map(|p| p.docs).max();
    if let Some(pd) = pysim_max {
        let pysim = pts.iter().find(|p| p.method == "minhashlsh-pysim" && p.docs == pd).unwrap();
        let lshb = pts.iter().find(|p| p.method == "lshbloom" && p.docs == pd).unwrap();
        println!(
            "headline (datasketch-calibrated) at {} docs: {:.1}x wall, {:.1}x disk (paper: 12x, 18x)",
            pd,
            pysim.wall_secs / lshb.wall_secs,
            pysim.disk_bytes as f64 / lshb.disk_bytes as f64
        );
    }
}
