//! Figure 1: wall-clock breakdown (MinHash vs index ops) for conventional
//! MinHashLSH and LSHBloom on a 10% peS2o-sim subset.
//!
//! Rows: rust-normalized MinHashLSH, the paper-calibrated datasketch
//! cost simulation, and LSHBloom. CSV at reports/fig1_breakdown.csv.
//!
//! `cargo bench --bench fig1_breakdown`   (LSHBLOOM_BENCH_QUICK=1 to shrink)

use lshbloom::eval::experiments::{fig1_breakdown, Scale};
use lshbloom::report::table::{f, Table};
use lshbloom::report::CsvWriter;
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let rows = fig1_breakdown(scale);

    let mut t = Table::new(
        "Fig 1 — wall clock breakdown (10% subset)",
        &["method", "minhash (s)", "index ops (s)", "other (s)", "total (s)", "index share"],
    );
    let mut csv = CsvWriter::create(
        Path::new("reports/fig1_breakdown.csv"),
        &["method", "docs", "minhash_secs", "index_secs", "other_secs", "wall_secs"],
    )
    .expect("csv");
    for b in &rows {
        t.row_disp(&[
            b.method.clone(),
            f(b.minhash_secs, 2),
            f(b.index_secs, 2),
            f(b.other_secs, 2),
            f(b.wall_secs, 2),
            format!("{:.0}%", 100.0 * b.index_secs / b.wall_secs.max(1e-9)),
        ]);
        csv.row_disp(&[
            b.method.clone(),
            b.docs.to_string(),
            b.minhash_secs.to_string(),
            b.index_secs.to_string(),
            b.other_secs.to_string(),
            b.wall_secs.to_string(),
        ])
        .unwrap();
    }
    csv.finish().unwrap();
    t.print();
    println!("(paper: index ops are >85% of datasketch MinHashLSH; LSHBloom is minhash-dominated)");
}
