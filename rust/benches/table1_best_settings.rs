//! Table 1: best hyperparameter settings per technique, selected by F1
//! over the Figs. 2–4 tuning grids.
//!
//! `cargo bench --bench table1_best_settings`

use lshbloom::eval::experiments::{table1, Scale};
use lshbloom::methods::MethodKind;
use lshbloom::report::table::{f, Table};
use lshbloom::report::CsvWriter;
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let best = table1(scale);

    let mut csv = CsvWriter::create(
        Path::new("reports/table1_best_settings.csv"),
        &["technique", "ngram", "threshold", "perms", "f1"],
    )
    .expect("csv");
    let mut t = Table::new(
        "Table 1 — best settings per technique",
        &["technique", "ngram", "threshold", "perms", "F1"],
    );
    for gp in &best {
        let ngram_cell = match gp.spec.kind {
            MethodKind::Dolma | MethodKind::CcNet | MethodKind::CcNetExact => "-".to_string(),
            _ => gp.spec.ngram.to_string(),
        };
        let perms_cell = match gp.spec.kind {
            MethodKind::MinHashLsh | MethodKind::LshBloom => gp.spec.num_perms.to_string(),
            _ => "-".to_string(),
        };
        t.row_disp(&[
            gp.spec.kind.name().to_string(),
            ngram_cell.clone(),
            format!("{}", gp.spec.threshold),
            perms_cell.clone(),
            f(gp.f1(), 4),
        ]);
        csv.row_disp(&[
            gp.spec.kind.name().to_string(),
            ngram_cell,
            gp.spec.threshold.to_string(),
            perms_cell,
            format!("{:.4}", gp.f1()),
        ])
        .unwrap();
    }
    csv.finish().unwrap();
    t.print();
    println!(
        "(paper Table 1: minhashlsh/lshbloom n=1 T=0.5; dolma-ngram/dclm n=5 T=0.2; \
         dolma/ccnet T=0.2)"
    );
}
