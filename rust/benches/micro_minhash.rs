//! MinHash signature throughput: the prepare-phase hot loop (Fig. 1's
//! dominant cost for LSHBloom), across permutation counts and families.
//!
//! `cargo bench --bench micro_minhash`

use lshbloom::corpus::{CorpusGenerator, GeneratorConfig};
use lshbloom::minhash::{MinHasher, PermFamily};
use lshbloom::perf::bench::Bencher;
use lshbloom::text::normalize;

fn main() {
    println!("# minhash signature computation (per document)\n");
    let g = CorpusGenerator::new(GeneratorConfig::default());
    let doc = normalize(&g.generate(0x3141, 0).text);
    let tokens = doc.split_whitespace().count();
    println!("document: {tokens} tokens\n");

    let mut b = Bencher::default();
    for perms in [32usize, 64, 128, 256] {
        for family in [PermFamily::Mix64, PermFamily::Datasketch] {
            let mh = MinHasher::new(family, perms, 1);
            let hashes = mh.shingle_hashes(&doc);
            let r = b.run(
                &format!("signature/p={perms}/{family:?}"),
                || mh.signature_of_hashes(&hashes),
            );
            println!("{}", r.report());
        }
    }

    println!();
    let mh = MinHasher::new(PermFamily::Mix64, 256, 1);
    let r = b.run("shingle+sha1/p=256", || mh.shingle_hashes(&doc));
    println!("{}", r.report());
    let r = b.run("normalize", || normalize(&g.generate(0x3141, 0).text));
    println!("{}", r.report());
    let full = b.run("full-prepare/p=256 (normalize+shingle+signature)", || {
        mh.signature(&doc)
    });
    println!("{}", full.report());
    println!(
        "\n  -> prepare-phase docs/s (single core, 256 perms): {:.0}",
        1e9 / full.median_ns()
    );
}
