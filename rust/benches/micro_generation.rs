//! Generational index: probe cost vs. generation count, and the
//! ingest-side price of watermark rotation.
//!
//! Two questions the capacity autopilot raises, answered on your
//! hardware:
//!
//! * `probe/...` — a probe ORs across every generation, so how does
//!   query throughput scale at 1/2/4/8 generations? Misses are the
//!   worst case (every generation's filter is consulted for every
//!   band); hits early-exit at the owning generation (probed
//!   newest-first, so old documents are the slow ones).
//! * `ingest/...` — rotation costs a strided fill sample every few
//!   thousand inserts plus the occasional freeze-and-reallocate. How
//!   much docs/sec does that shave off a rotation-disabled ingest of
//!   the same stream?
//!
//! Reports the same single-line text shape as the other `micro_*`
//! benches plus one machine-readable JSON summary line (crate `json`
//! module) for harness scripts.
//!
//! `cargo bench --bench micro_generation` (LSHBLOOM_BENCH_FAST=1 for a
//! quick pass)

use lshbloom::engine::ConcurrentLshBloomIndex;
use lshbloom::index::lshbloom::LshBloomConfig;
use lshbloom::json::{obj, Value};
use lshbloom::minhash::LshParams;
use lshbloom::perf::bench::{fmt_count, time_once};
use lshbloom::rng::Xoshiro256pp;

// The paper's extreme-scale band geometry (T=0.8, 128 perms).
const LSH: LshParams = LshParams { num_bands: 9, rows_per_band: 13 };

fn random_doc(rng: &mut Xoshiro256pp) -> Vec<u64> {
    (0..LSH.num_bands).map(|_| rng.next_u64()).collect()
}

/// An index grown to exactly `generations` generations by streaming
/// unique documents through watermark rotation (plus a quarter-plan of
/// documents into the open generation so it is never empty). Returns
/// the index and every document it holds.
fn grown_index(
    generations: usize,
    per_gen: u64,
    rng: &mut Xoshiro256pp,
) -> (ConcurrentLshBloomIndex, Vec<Vec<u64>>) {
    let mut index = ConcurrentLshBloomIndex::new(LshBloomConfig::new(LSH, 1e-10, per_gen));
    index.enable_rotation(0.5);
    let mut held = Vec::new();
    // Hard cap so a sizing bug degrades to a short bench, not a hang.
    let cap = (generations as u64 * per_gen).saturating_mul(8);
    while index.num_generations() < generations && (held.len() as u64) < cap {
        let doc = random_doc(rng);
        index.insert_if_new_shared(&doc);
        held.push(doc);
    }
    for _ in 0..per_gen / 4 {
        let doc = random_doc(rng);
        index.insert_if_new_shared(&doc);
        held.push(doc);
    }
    assert_eq!(index.num_generations(), generations, "bench corpus failed to grow the index");
    (index, held)
}

fn main() {
    println!("# generational index: probe cost and rotation overhead\n");
    let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let per_gen: u64 = if fast { 1_000 } else { 10_000 };
    let probes: usize = if fast { 20_000 } else { 200_000 };
    let mut rng = Xoshiro256pp::seeded(0x9E37_79B9_7F4A_7C15);

    let mut results: Vec<Value> = Vec::new();
    for &gens in &[1usize, 2, 4, 8] {
        let (index, held) = grown_index(gens, per_gen, &mut rng);

        // Misses: fresh random vectors, absent from every generation.
        let miss_docs: Vec<Vec<u64>> = (0..probes).map(|_| random_doc(&mut rng)).collect();
        let (miss_hits, wall) = time_once(|| {
            let mut hits = 0usize;
            for doc in &miss_docs {
                hits += index.query(doc) as usize;
            }
            hits
        });
        let miss_rate = probes as f64 / wall.as_secs_f64();

        // Hits: resident documents sampled uniformly across generations.
        let hit_docs: Vec<&Vec<u64>> =
            (0..probes).map(|i| &held[(i * 2_654_435_761) % held.len()]).collect();
        let (hit_hits, wall) = time_once(|| {
            let mut hits = 0usize;
            for doc in &hit_docs {
                hits += index.query(doc) as usize;
            }
            hits
        });
        let hit_rate = probes as f64 / wall.as_secs_f64();
        assert_eq!(hit_hits, probes, "a resident document must always probe true");

        println!(
            "{:<44} {:>12}/s   ({} false positives)",
            format!("probe/miss/generations={gens}"),
            fmt_count(miss_rate),
            miss_hits
        );
        println!(
            "{:<44} {:>12}/s",
            format!("probe/hit/generations={gens}"),
            fmt_count(hit_rate)
        );
        results.push(obj(vec![
            ("generations", Value::u64(gens as u64)),
            ("miss_probes_per_sec", Value::num(miss_rate)),
            ("hit_probes_per_sec", Value::num(hit_rate)),
        ]));
    }
    println!();

    // Ingest price of rotation: the same 3-plan stream into a rotating
    // index vs. a fixed-size one left to saturate.
    let stream: Vec<Vec<u64>> =
        (0..per_gen * 3).map(|_| random_doc(&mut rng)).collect();
    let mut rotating = ConcurrentLshBloomIndex::new(LshBloomConfig::new(LSH, 1e-10, per_gen));
    rotating.enable_rotation(0.5);
    let (_, wall) = time_once(|| {
        for doc in &stream {
            rotating.insert_if_new_shared(doc);
        }
    });
    let rotating_rate = stream.len() as f64 / wall.as_secs_f64();

    let fixed = ConcurrentLshBloomIndex::new(LshBloomConfig::new(LSH, 1e-10, per_gen));
    let (_, wall) = time_once(|| {
        for doc in &stream {
            fixed.insert_if_new_shared(doc);
        }
    });
    let fixed_rate = stream.len() as f64 / wall.as_secs_f64();

    println!(
        "{:<44} {:>12}/s   ({} rotations)",
        "ingest/rotating",
        fmt_count(rotating_rate),
        rotating.rotations()
    );
    println!(
        "{:<44} {:>12}/s   ({:.2}x vs rotating)",
        "ingest/fixed-size",
        fmt_count(fixed_rate),
        fixed_rate / rotating_rate
    );

    let summary = obj(vec![
        ("bench", Value::str("micro_generation")),
        ("per_generation_docs", Value::u64(per_gen)),
        ("probes", Value::u64(probes as u64)),
        ("results", Value::Arr(results)),
        ("ingest_rotating_docs_per_sec", Value::num(rotating_rate)),
        ("ingest_fixed_docs_per_sec", Value::num(fixed_rate)),
        ("rotations", Value::u64(rotating.rotations())),
    ]);
    println!("{}", summary.to_json());
}
