//! XLA artifact backend vs native rust backend for the same band-hash
//! computation (identical bits, different execution engines).
//!
//! On this CPU testbed the artifact runs the interpret-mode Pallas
//! lowering, so native wins; the artifact path exists to prove the
//! three-layer architecture and to be the TPU deployment story (see
//! DESIGN.md §Hardware-Adaptation).
//!
//! `cargo bench --bench micro_xla_vs_native`

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{CorpusGenerator, Doc, GeneratorConfig};
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::methods::Preparer;
use lshbloom::minhash::PermFamily;
use lshbloom::perf::bench::Bencher;
use lshbloom::runtime::XlaBandPreparer;
use std::path::Path;

fn main() {
    println!("# batched band-hash preparation: XLA artifacts vs native rust\n");
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return;
    }

    let g = CorpusGenerator::new(GeneratorConfig::short());
    let batch: Vec<Doc> = (0..64).map(|i| g.generate(0xA0, i)).collect();

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 256,
        expected_docs: 10_000,
        ..Default::default()
    };
    let native = lshbloom_method(&cfg, PermFamily::Mix64);
    let xla = XlaBandPreparer::from_manifest(dir, 0.5, 256, 1).expect("artifacts");

    let mut b = Bencher::default().throughput(batch.len() as u64);
    let rn = b.run("prepare_batch/native/p=256/b=64docs", || {
        native.preparer.prepare_batch(&batch)
    });
    println!("{}", rn.report());
    let rx = b.run("prepare_batch/xla/p=256/b=64docs", || {
        xla.prepare_batch(&batch)
    });
    println!("{}", rx.report());
    println!(
        "\n  -> native/xla ratio on CPU: {:.2}x (artifact path is the TPU story; \
         numerics are bit-identical — see rust/tests/xla_backend.rs)",
        rx.median_ns() / rn.median_ns()
    );
}
