//! Sharded-aggregation throughput: `dedup_sharded` docs/sec as the
//! shard count grows on one corpus (§6 path, engine-backed).
//!
//! Phase 1 parallelism scales with shards (each shard runs its own
//! `ConcurrentEngine`); phase 2 is the bit-OR union of shard filters
//! plus a band-hash recheck per survivor, so its cost is reported
//! separately — the point of the merge-by-union design is that phase 2
//! stays a small, MinHash-free fraction of the run at every shard
//! count.
//!
//! Reports the same single-line text shape as the other `micro_*`
//! benches plus one machine-readable JSON summary line (crate `json`
//! module) for harness scripts.
//!
//! `cargo bench --bench micro_shard` (LSHBLOOM_BENCH_FAST=1 for a
//! quick pass)

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{CorpusGenerator, Doc, GeneratorConfig};
use lshbloom::json::{obj, Value};
use lshbloom::perf::bench::{fmt_count, time_once};
use lshbloom::pipeline::dedup_sharded;

fn main() {
    println!("# sharded dedup throughput vs shard count (docs/sec)\n");
    let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n: usize = if fast { 1_200 } else { 8_000 };

    // Generated corpus with ~25% exact twins spread across the stream so
    // both the within-shard (phase 1) and cross-shard (phase 2) drop
    // paths stay hot at every shard count.
    let g = CorpusGenerator::new(GeneratorConfig::short());
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 4 == 3 && i >= 17 {
            let prev = docs[(i - 17) as usize].clone();
            docs.push(Doc { id: i, ..prev });
        } else {
            docs.push(g.generate(0x5AAD, i));
        }
    }

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        p_effective: 1e-10,
        expected_docs: n as u64,
        ..Default::default()
    };

    let mut results: Vec<Value> = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        let input = docs.clone();
        let (stats, wall) = time_once(|| dedup_sharded(&cfg, input, shards));
        let docs_per_sec = n as f64 / wall.as_secs_f64();
        let p2_frac = stats.phase2_wall.as_secs_f64() / wall.as_secs_f64().max(1e-9);
        println!(
            "{:<44} {:>12}/s   (p1 drop {}, p2 drop {}, p2 {:.1}% of wall)",
            format!("sharded/shards={shards}"),
            fmt_count(docs_per_sec),
            stats.phase1_dropped,
            stats.phase2_dropped,
            p2_frac * 100.0
        );
        results.push(obj(vec![
            ("shards", Value::u64(shards as u64)),
            ("docs_per_sec", Value::num(docs_per_sec)),
            ("phase1_dropped", Value::u64(stats.phase1_dropped)),
            ("phase2_dropped", Value::u64(stats.phase2_dropped)),
            ("survivors", Value::u64(stats.survivors.len() as u64)),
            ("phase2_wall_frac", Value::num(p2_frac)),
        ]));
    }
    println!();

    let summary = obj(vec![
        ("bench", Value::str("micro_shard")),
        ("docs", Value::u64(n as u64)),
        ("results", Value::Arr(results)),
    ]);
    println!("{}", summary.to_json());
}
