//! Figure 6: pareto plots — F1 vs runtime (a) and F1 vs disk usage (b)
//! on the balanced 50%-duplicates testing corpus.
//!
//! `cargo bench --bench fig6_pareto`

use lshbloom::eval::experiments::{fig6_pareto, Scale};
use lshbloom::report::table::{bytes, f, Table};
use lshbloom::report::{line_plot, CsvWriter, Series};
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let results = fig6_pareto(scale);

    let mut csv = CsvWriter::create(
        Path::new("reports/fig6_pareto.csv"),
        &["method", "f1", "wall_secs", "disk_bytes"],
    )
    .expect("csv");
    let mut t = Table::new(
        "Fig 6 — F1 vs resource usage (50% dup corpus)",
        &["method", "F1", "runtime (s)", "disk"],
    );
    let mut rt_series = Vec::new();
    let mut disk_series = Vec::new();
    for r in &results {
        t.row_disp(&[
            r.method.clone(),
            f(r.confusion.f1(), 4),
            f(r.wall_secs, 2),
            bytes(r.disk_bytes),
        ]);
        csv.row_disp(&[
            r.method.clone(),
            format!("{:.4}", r.confusion.f1()),
            format!("{:.3}", r.wall_secs),
            r.disk_bytes.to_string(),
        ])
        .unwrap();
        rt_series.push(Series::new(r.method.clone(), vec![(r.wall_secs, r.confusion.f1())]));
        disk_series.push(Series::new(
            r.method.clone(),
            vec![(r.disk_bytes as f64 / 1e6, r.confusion.f1())],
        ));
    }
    csv.finish().unwrap();
    t.print();
    println!("{}", line_plot("Fig 6a — F1 vs runtime", "seconds", "F1", &rt_series));
    println!("{}", line_plot("Fig 6b — F1 vs disk", "MB", "F1", &disk_series));
    println!("(paper: MinHashLSH & LSHBloom dominate; LSHBloom at a fraction of the disk)");
}
