//! Figure 4: F1 vs overlap threshold for the paragraph-level techniques
//! (Dolma, CCNet) on the tuning corpus.
//!
//! `cargo bench --bench fig4_paragraph`

use lshbloom::eval::experiments::{fig4_sweeps, Scale};
use lshbloom::report::{line_plot, CsvWriter, Series};
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let mut csv = CsvWriter::create(
        Path::new("reports/fig4_paragraph.csv"),
        &["method", "threshold", "precision", "recall", "f1"],
    )
    .expect("csv");

    let mut series = Vec::new();
    for (kind, pts) in fig4_sweeps(scale) {
        let mut points = Vec::new();
        for gp in &pts {
            points.push((gp.spec.threshold, gp.f1()));
            csv.row_disp(&[
                kind.name().to_string(),
                gp.spec.threshold.to_string(),
                format!("{:.4}", gp.result.confusion.precision()),
                format!("{:.4}", gp.result.confusion.recall()),
                format!("{:.4}", gp.f1()),
            ])
            .unwrap();
        }
        series.push(Series::new(kind.name(), points));
    }
    csv.finish().unwrap();
    println!("{}", line_plot("Fig 4 — paragraph-level F1 vs threshold", "threshold", "F1", &series));
    println!("(paper: paragraph methods peak at low T=0.2 and underperform overall)");
}
