//! Serving-tier throughput: one concurrent engine vs N in-process band
//! slices vs a router fanning over N loopback slice servers.
//!
//! All three paths produce identical verdicts (the OR-reduce /
//! reconcile parity that `tests/serving_tier.rs` asserts); what differs
//! is where the work lands. The in-process slices add parallel slice
//! probes on top of the engine's pooled MinHash; the router adds one
//! JSON round trip per batch and a TCP hop per slice, which is the
//! price of splitting the filter memory across hosts — this bench puts
//! a number on each step.
//!
//! Reports the same single-line text shape as the other `micro_*`
//! benches plus one machine-readable JSON summary line (crate `json`
//! module) for harness scripts.
//!
//! `cargo bench --bench micro_route` (LSHBLOOM_BENCH_FAST=1 for a
//! quick pass)

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::corpus::{CorpusGenerator, Doc, GeneratorConfig};
use lshbloom::engine::{BandShardedEngine, ConcurrentEngine};
use lshbloom::json::{obj, Value};
use lshbloom::perf::bench::{fmt_count, time_once};
use lshbloom::service::{DedupClient, DedupRouter, DedupServer, RouterOptions, ServeOptions};

fn report(name: &str, n: usize, dups: usize, wall: std::time::Duration, out: &mut Vec<Value>) {
    let docs_per_sec = n as f64 / wall.as_secs_f64();
    println!("{:<44} {:>12}/s   ({dups} duplicates)", name, fmt_count(docs_per_sec));
    out.push(obj(vec![
        ("variant", Value::str(name)),
        ("docs_per_sec", Value::num(docs_per_sec)),
        ("duplicates", Value::u64(dups as u64)),
    ]));
}

/// Start `slices x replicas` loopback slice servers; returns (join
/// handles, one router backend spec per slice — the replicas of a
/// slice joined with `|`, the `--backends` syntax).
fn start_fleet(
    cfg: &PipelineConfig,
    slices: usize,
    replicas: usize,
) -> (Vec<std::thread::JoinHandle<()>>, Vec<String>) {
    let mut handles = Vec::with_capacity(slices * replicas);
    let mut specs = Vec::with_capacity(slices);
    for slice in 0..slices {
        let mut addrs = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            let opts = ServeOptions { slice: Some((slice, slices)), ..ServeOptions::default() };
            let server =
                DedupServer::bind_with_opts("127.0.0.1:0", cfg, &opts).expect("bind slice");
            addrs.push(server.local_addr().unwrap().to_string());
            handles.push(std::thread::spawn(move || server.serve().expect("serve")));
        }
        specs.push(addrs.join("|"));
    }
    (handles, specs)
}

/// One router variant: `slices x replicas` loopback backends, the same
/// batched stream. R=2 pays a second insert fan-out per slice — the
/// price of replica redundancy — while probes cost the same OR.
fn run_router_variant(
    name: &str,
    cfg: &PipelineConfig,
    slices: usize,
    replicas: usize,
    docs: &[Doc],
    batch: usize,
    results: &mut Vec<Value>,
) {
    let (handles, specs) = start_fleet(cfg, slices, replicas);
    let router = DedupRouter::bind("127.0.0.1:0", cfg, specs.clone(), &RouterOptions::default())
        .expect("bind router");
    let router_addr = router.local_addr().unwrap().to_string();
    let router_handle = std::thread::spawn(move || router.serve().expect("route"));
    let mut client = DedupClient::connect(&router_addr).expect("connect router");
    let (dups, wall) = time_once(|| {
        let mut dups = 0usize;
        for chunk in docs.chunks(batch) {
            let texts: Vec<&str> = chunk.iter().map(|d| d.text.as_str()).collect();
            let verdicts = client.check_batch(&texts).expect("route check_batch");
            dups += verdicts.into_iter().filter(|&d| d).count();
        }
        dups
    });
    report(name, docs.len(), dups, wall, results);
    client.shutdown().expect("router shutdown");
    router_handle.join().unwrap();
    for addr in specs.iter().flat_map(|s| s.split('|')) {
        DedupClient::connect(addr).unwrap().shutdown().unwrap();
    }
    for handle in handles {
        handle.join().unwrap();
    }
}

fn main() {
    println!("# serving tier: engine vs band slices vs loopback router (docs/sec)\n");
    let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    // Trace sampling probability for the router variant (the CI smoke
    // runs this bench at 0 and at 1.0 to bound the tracing overhead).
    let trace_sample: f64 = std::env::var("LSHBLOOM_TRACE_SAMPLE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    let n: usize = if fast { 1_500 } else { 10_000 };
    let batch = 64usize;

    // Generated corpus with ~25% exact twins spread across the stream so
    // both the fresh-insert and duplicate paths stay hot everywhere.
    let g = CorpusGenerator::new(GeneratorConfig::short());
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 4 == 3 && i >= 17 {
            let prev = docs[(i - 17) as usize].clone();
            docs.push(Doc { id: i, ..prev });
        } else {
            docs.push(g.generate(0x5EED, i));
        }
    }

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        p_effective: 1e-10,
        expected_docs: n as u64,
        engine: EngineMode::Concurrent,
        trace_sample,
        ..Default::default()
    };

    let mut results: Vec<Value> = Vec::new();

    // Baseline: one concurrent engine, batched submit.
    {
        let engine = ConcurrentEngine::from_config(&cfg);
        let input = docs.clone();
        let (dups, wall) = time_once(|| {
            let mut dups = 0usize;
            for chunk in input.chunks(batch) {
                let verdicts = engine.submit(chunk.to_vec());
                dups += verdicts.iter().filter(|d| d.duplicate).count();
            }
            dups
        });
        report("engine/slices=1", n, dups, wall, &mut results);
    }

    // In-process band slices (serve --serve-shards N's backend).
    for &slices in &[2usize, 4] {
        let engine = BandShardedEngine::from_config(&cfg, slices);
        let input = docs.clone();
        let (dups, wall) = time_once(|| {
            let mut dups = 0usize;
            for chunk in input.chunks(batch) {
                let verdicts = engine.submit(chunk.to_vec());
                dups += verdicts.iter().filter(|d| d.duplicate).count();
            }
            dups
        });
        report(&format!("engine/slices={slices}"), n, dups, wall, &mut results);
    }

    // Router over loopback slice servers: the same batches, now paying
    // one MinHash at the router plus a TCP fan-out per batch. This
    // variant must stay first among the `router/` entries — the CI
    // trace-overhead gate reads the first one from the JSON summary.
    run_router_variant("router/loopback-slices=4", &cfg, 4, 1, &docs, batch, &mut results);

    // Replication cost: the same 2-slice fleet unreplicated vs R=2.
    // Inserts fan to both replicas of each slice, so the delta between
    // these two rates is the throughput price of replica redundancy.
    run_router_variant("router/loopback-slices=2", &cfg, 2, 1, &docs, batch, &mut results);
    run_router_variant(
        "router/loopback-slices=2-replicas=2",
        &cfg,
        2,
        2,
        &docs,
        batch,
        &mut results,
    );

    println!();
    let summary = obj(vec![
        ("bench", Value::str("micro_route")),
        ("docs", Value::u64(n as u64)),
        ("batch", Value::u64(batch as u64)),
        ("trace_sample", Value::num(trace_sample)),
        ("results", Value::Arr(results)),
    ]);
    println!("{}", summary.to_json());
}
