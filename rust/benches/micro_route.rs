//! Serving-tier throughput: one concurrent engine vs N in-process band
//! slices vs a router fanning over N loopback slice servers.
//!
//! All three paths produce identical verdicts (the OR-reduce /
//! reconcile parity that `tests/serving_tier.rs` asserts); what differs
//! is where the work lands. The in-process slices add parallel slice
//! probes on top of the engine's pooled MinHash; the router adds one
//! JSON round trip per batch and a TCP hop per slice, which is the
//! price of splitting the filter memory across hosts — this bench puts
//! a number on each step.
//!
//! Reports the same single-line text shape as the other `micro_*`
//! benches plus one machine-readable JSON summary line (crate `json`
//! module) for harness scripts.
//!
//! `cargo bench --bench micro_route` (LSHBLOOM_BENCH_FAST=1 for a
//! quick pass)

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::corpus::{CorpusGenerator, Doc, GeneratorConfig};
use lshbloom::engine::{BandShardedEngine, ConcurrentEngine};
use lshbloom::json::{obj, Value};
use lshbloom::perf::bench::{fmt_count, time_once};
use lshbloom::service::{DedupClient, DedupRouter, DedupServer, RouterOptions, ServeOptions};

fn report(name: &str, n: usize, dups: usize, wall: std::time::Duration, out: &mut Vec<Value>) {
    let docs_per_sec = n as f64 / wall.as_secs_f64();
    println!("{:<44} {:>12}/s   ({dups} duplicates)", name, fmt_count(docs_per_sec));
    out.push(obj(vec![
        ("variant", Value::str(name)),
        ("docs_per_sec", Value::num(docs_per_sec)),
        ("duplicates", Value::u64(dups as u64)),
    ]));
}

/// Start `count` loopback slice servers; returns (join handles, addrs).
fn start_fleet(
    cfg: &PipelineConfig,
    count: usize,
) -> (Vec<std::thread::JoinHandle<()>>, Vec<String>) {
    let mut handles = Vec::with_capacity(count);
    let mut addrs = Vec::with_capacity(count);
    for slice in 0..count {
        let opts = ServeOptions { slice: Some((slice, count)), ..ServeOptions::default() };
        let server =
            DedupServer::bind_with_opts("127.0.0.1:0", cfg, &opts).expect("bind slice");
        addrs.push(server.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || server.serve().expect("serve")));
    }
    (handles, addrs)
}

fn main() {
    println!("# serving tier: engine vs band slices vs loopback router (docs/sec)\n");
    let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    // Trace sampling probability for the router variant (the CI smoke
    // runs this bench at 0 and at 1.0 to bound the tracing overhead).
    let trace_sample: f64 = std::env::var("LSHBLOOM_TRACE_SAMPLE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    let n: usize = if fast { 1_500 } else { 10_000 };
    let batch = 64usize;

    // Generated corpus with ~25% exact twins spread across the stream so
    // both the fresh-insert and duplicate paths stay hot everywhere.
    let g = CorpusGenerator::new(GeneratorConfig::short());
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 4 == 3 && i >= 17 {
            let prev = docs[(i - 17) as usize].clone();
            docs.push(Doc { id: i, ..prev });
        } else {
            docs.push(g.generate(0x5EED, i));
        }
    }

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        p_effective: 1e-10,
        expected_docs: n as u64,
        engine: EngineMode::Concurrent,
        trace_sample,
        ..Default::default()
    };

    let mut results: Vec<Value> = Vec::new();

    // Baseline: one concurrent engine, batched submit.
    {
        let engine = ConcurrentEngine::from_config(&cfg);
        let input = docs.clone();
        let (dups, wall) = time_once(|| {
            let mut dups = 0usize;
            for chunk in input.chunks(batch) {
                let verdicts = engine.submit(chunk.to_vec());
                dups += verdicts.iter().filter(|d| d.duplicate).count();
            }
            dups
        });
        report("engine/slices=1", n, dups, wall, &mut results);
    }

    // In-process band slices (serve --serve-shards N's backend).
    for &slices in &[2usize, 4] {
        let engine = BandShardedEngine::from_config(&cfg, slices);
        let input = docs.clone();
        let (dups, wall) = time_once(|| {
            let mut dups = 0usize;
            for chunk in input.chunks(batch) {
                let verdicts = engine.submit(chunk.to_vec());
                dups += verdicts.iter().filter(|d| d.duplicate).count();
            }
            dups
        });
        report(&format!("engine/slices={slices}"), n, dups, wall, &mut results);
    }

    // Router over loopback slice servers: the same batches, now paying
    // one MinHash at the router plus a TCP fan-out per batch.
    {
        let slices = 4usize;
        let (handles, addrs) = start_fleet(&cfg, slices);
        let router =
            DedupRouter::bind("127.0.0.1:0", &cfg, addrs.clone(), &RouterOptions::default())
                .expect("bind router");
        let router_addr = router.local_addr().unwrap().to_string();
        let router_handle = std::thread::spawn(move || router.serve().expect("route"));
        let mut client = DedupClient::connect(&router_addr).expect("connect router");
        let (dups, wall) = time_once(|| {
            let mut dups = 0usize;
            for chunk in docs.chunks(batch) {
                let texts: Vec<&str> = chunk.iter().map(|d| d.text.as_str()).collect();
                let verdicts = client.check_batch(&texts).expect("route check_batch");
                dups += verdicts.into_iter().filter(|&d| d).count();
            }
            dups
        });
        report(&format!("router/loopback-slices={slices}"), n, dups, wall, &mut results);
        client.shutdown().expect("router shutdown");
        router_handle.join().unwrap();
        for addr in &addrs {
            DedupClient::connect(addr).unwrap().shutdown().unwrap();
        }
        for handle in handles {
            handle.join().unwrap();
        }
    }

    println!();
    let summary = obj(vec![
        ("bench", Value::str("micro_route")),
        ("docs", Value::u64(n as u64)),
        ("batch", Value::u64(batch as u64)),
        ("trace_sample", Value::num(trace_sample)),
        ("results", Value::Arr(results)),
    ]);
    println!("{}", summary.to_json());
}
