//! Table 2: extrapolated index storage at 5B and 100B documents —
//! closed-form LSHBloom sizes (§4.5) vs the linear MinHashLSH model,
//! plus the paper's measured datasketch footprint for reference.
//!
//! `cargo bench --bench table2_index_size`

use lshbloom::eval::experiments::table2_rows;
use lshbloom::report::table::{bytes, Table};
use lshbloom::report::CsvWriter;
use std::path::Path;

fn main() {
    let rows = table2_rows();

    let mut csv = CsvWriter::create(
        Path::new("reports/table2_index_size.csv"),
        &["n_docs", "p_effective", "lshbloom_bytes", "minhashlsh_bytes", "advantage"],
    )
    .expect("csv");
    let mut t = Table::new(
        "Table 2 — extrapolated index storage (T=0.5, P=256 -> b=42, r=6)",
        &["N docs", "bloom FP overhead", "LSHBloom", "MinHashLSH (rust model)", "advantage"],
    );
    for r in &rows {
        let fp_label = if (r.p_effective - 1.0 / r.n as f64).abs() / r.p_effective < 1e-9 {
            "1/N".to_string()
        } else {
            format!("{:.0e}", r.p_effective)
        };
        t.row_disp(&[
            format!("{:.0e}", r.n as f64),
            fp_label.clone(),
            bytes(r.lshbloom_bytes),
            bytes(r.minhashlsh_bytes),
            format!("{:.1}x", r.advantage()),
        ]);
        csv.row_disp(&[
            r.n.to_string(),
            r.p_effective.to_string(),
            r.lshbloom_bytes.to_string(),
            r.minhashlsh_bytes.to_string(),
            format!("{:.2}", r.advantage()),
        ])
        .unwrap();
    }
    csv.finish().unwrap();
    t.print();

    // Paper cross-check: the N=1e11 column of the paper's Table 2 is
    // reproduced exactly by the closed form; the datasketch row uses the
    // paper's measured 5.55 kB/doc footprint.
    let mut t = Table::new(
        "paper cross-check (datasketch measured footprint, 5.55 kB/doc)",
        &["N docs", "MinHashLSH (paper)", "LSHBloom p=1e-5 (ours)", "advantage"],
    );
    for n in [5_000_000_000u64, 100_000_000_000] {
        let ds = (n as f64 * 5553.5) as u64;
        let ours = rows
            .iter()
            .find(|r| r.n == n && (r.p_effective - 1e-5).abs() < 1e-9)
            .unwrap()
            .lshbloom_bytes;
        t.row_disp(&[
            format!("{:.0e}", n as f64),
            bytes(ds),
            bytes(ours),
            format!("{:.1}x", ds as f64 / ours as f64),
        ]);
    }
    t.print();
    println!(
        "(paper Table 2 at N=1e11: LSHBloom 16.66/24.21/31.76 TB for p=1e-5/1e-8/1/N —\n\
         our closed form matches to three decimals; the paper's N=5e9 column is\n\
         internally inconsistent with its own linear-in-n formula, see EXPERIMENTS.md)"
    );
}
