//! Figure 2: F1 heatmaps for MinHashLSH and LSHBloom over
//! (number of permutations × Jaccard threshold) on the tuning corpus.
//!
//! `cargo bench --bench fig2_lsh_grid`

use lshbloom::eval::experiments::{fig2_grids, Scale};
use lshbloom::eval::tuner::ranges;
use lshbloom::report::{heatmap, CsvWriter};
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let mut csv = CsvWriter::create(
        Path::new("reports/fig2_lsh_grid.csv"),
        &["method", "threshold", "perms", "precision", "recall", "f1"],
    )
    .expect("csv");

    for (kind, pts) in fig2_grids(scale) {
        // Rows = thresholds, cols = permutation counts.
        let rows: Vec<String> = ranges::THRESHOLDS.iter().map(|t| format!("T={t}")).collect();
        let cols: Vec<String> = ranges::PERMS.iter().map(|p| format!("P={p}")).collect();
        let mut grid = vec![vec![0.0; ranges::PERMS.len()]; ranges::THRESHOLDS.len()];
        for gp in &pts {
            let ri = ranges::THRESHOLDS.iter().position(|&t| t == gp.spec.threshold).unwrap();
            let ci = ranges::PERMS.iter().position(|&p| p == gp.spec.num_perms).unwrap();
            grid[ri][ci] = gp.f1();
            csv.row_disp(&[
                kind.name().to_string(),
                gp.spec.threshold.to_string(),
                gp.spec.num_perms.to_string(),
                format!("{:.4}", gp.result.confusion.precision()),
                format!("{:.4}", gp.result.confusion.recall()),
                format!("{:.4}", gp.f1()),
            ])
            .unwrap();
        }
        println!("{}", heatmap(&format!("Fig 2 — {} F1", kind.name()), &rows, &cols, &grid));
    }
    csv.finish().unwrap();
    println!("(paper: best at T=0.5; F1 improves with permutations; diminishing beyond 128)");
}
