//! Figure 8: extrapolated wall-clock time per method at extreme scale
//! (linear fits over the Fig. 7 measurements, projected to 5B docs).
//!
//! `cargo bench --bench fig8_extrapolation`

use lshbloom::eval::experiments::{fig7_scaling, fig8_extrapolate, Scale};
use lshbloom::report::table::Table;
use lshbloom::report::CsvWriter;
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let pts = fig7_scaling(scale, &[0.1, 0.25, 0.5, 0.75, 1.0]);
    let targets = [1_000_000u64, 39_000_000, 5_000_000_000];
    let proj = fig8_extrapolate(&pts, &targets);

    let mut csv = CsvWriter::create(
        Path::new("reports/fig8_extrapolation.csv"),
        &["method", "target_docs", "projected_secs", "projected_days"],
    )
    .expect("csv");
    let mut t = Table::new(
        "Fig 8 — extrapolated runtime (single-node, linear fit)",
        &["method", "39M docs (peS2o)", "5B docs"],
    );
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for (m, targets_out) in &proj {
        let f39 = targets_out.iter().find(|(n, _)| *n == 39_000_000).unwrap().1;
        let f5b = targets_out.iter().find(|(n, _)| *n == 5_000_000_000).unwrap().1;
        rows.push((m.clone(), f39, f5b));
        for (n, secs) in targets_out {
            csv.row_disp(&[
                m.clone(),
                n.to_string(),
                format!("{secs:.0}"),
                format!("{:.2}", secs / 86_400.0),
            ])
            .unwrap();
        }
    }
    csv.finish().unwrap();
    for (m, f39, f5b) in &rows {
        t.row_disp(&[
            m.clone(),
            format!("{:.1} h", f39 / 3600.0),
            format!("{:.1} days", f5b / 86_400.0),
        ]);
    }
    t.print();

    let get = |name: &str| rows.iter().find(|(m, _, _)| m == name).map(|r| r.2);
    if let (Some(lshb), Some(mlsh)) = (get("lshbloom"), get("minhashlsh")) {
        println!("rust-normalized 5B-doc speedup: {:.1}x", mlsh / lshb);
    }
    println!("(paper: datasketch MinHashLSH ~200 days vs LSHBloom ~15 days at 5B -> 13x;");
    println!(" the datasketch-calibrated projection is 2.9ms/doc * 5e9 = 168 days, matching)");
}
