//! Concurrent engine vs. mutex-serialized decider: ingest docs/sec at
//! 1/2/4/8 threads on the same generated corpus.
//!
//! Three contenders, all consuming identical documents:
//!
//! * `mutex`  — the naive shared-state integration the engine replaces:
//!   every per-document operation (MinHash + decide) runs inside one
//!   global `Mutex<LshBloomDecider>` critical section, so throughput is
//!   capped at one core regardless of thread count.
//! * `mutex-prepare-out` — the seed server's fine-grained variant:
//!   MinHash on the calling thread, only `decide` under the lock.
//! * `engine` — `ConcurrentEngine::submit`: scoped-pool MinHash +
//!   lock-free atomic-Bloom index, no global lock anywhere.
//!
//! Reports the same single-line text shape as the other `micro_*`
//! benches plus one machine-readable JSON summary line (crate `json`
//! module) for harness scripts.
//!
//! `cargo bench --bench micro_engine` (LSHBLOOM_BENCH_FAST=1 for a
//! quick pass)

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{CorpusGenerator, Doc, GeneratorConfig};
use lshbloom::engine::ConcurrentEngine;
use lshbloom::json::{obj, Value};
use lshbloom::methods::lshbloom::{decider_from_config, BandPreparer};
use lshbloom::methods::{Decider, Preparer};
use lshbloom::perf::bench::{fmt_count, time_once};
use std::sync::Mutex;

/// Whole-operation critical section: throughput ceiling = one core.
fn run_mutex_coarse(docs: &[Doc], threads: usize, cfg: &PipelineConfig) -> f64 {
    let preparer = BandPreparer::from_config(cfg);
    let decider = Mutex::new(decider_from_config(cfg, preparer.lsh));
    let (_, wall) = time_once(|| {
        std::thread::scope(|s| {
            for chunk in docs.chunks(docs.len().div_ceil(threads)) {
                let (preparer, decider) = (&preparer, &decider);
                s.spawn(move || {
                    for doc in chunk {
                        let mut d = decider.lock().unwrap();
                        let prepared = preparer.prepare_batch(std::slice::from_ref(doc));
                        d.decide(&prepared[0]);
                    }
                });
            }
        });
    });
    docs.len() as f64 / wall.as_secs_f64()
}

/// Seed-server shape: MinHash parallel, only decide under the lock.
fn run_mutex_fine(docs: &[Doc], threads: usize, cfg: &PipelineConfig) -> f64 {
    let preparer = BandPreparer::from_config(cfg);
    let decider = Mutex::new(decider_from_config(cfg, preparer.lsh));
    let (_, wall) = time_once(|| {
        std::thread::scope(|s| {
            for chunk in docs.chunks(docs.len().div_ceil(threads)) {
                let (preparer, decider) = (&preparer, &decider);
                s.spawn(move || {
                    for doc in chunk {
                        let prepared = preparer.prepare_batch(std::slice::from_ref(doc));
                        decider.lock().unwrap().decide(&prepared[0]);
                    }
                });
            }
        });
    });
    docs.len() as f64 / wall.as_secs_f64()
}

/// Lock-free engine, batched submits sized to keep the pool saturated.
fn run_engine(docs: &[Doc], threads: usize, cfg: &PipelineConfig) -> f64 {
    let mut cfg = cfg.clone();
    cfg.workers = threads;
    let engine = ConcurrentEngine::from_config(&cfg);
    let super_batch = (threads * 128).max(256);
    // Materialize the batches up front: the mutex contenders borrow
    // `docs`, so cloning inside the timed loop would bill allocation +
    // memcpy to the engine lane only and understate its speedup.
    let batches: Vec<Vec<Doc>> = docs.chunks(super_batch).map(|c| c.to_vec()).collect();
    let (_, wall) = time_once(|| {
        for batch in batches {
            engine.submit(batch);
        }
    });
    docs.len() as f64 / wall.as_secs_f64()
}

fn main() {
    println!("# concurrent engine vs mutex-serialized decider (docs/sec)\n");
    let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n: usize = if fast { 600 } else { 4_000 };

    // Generated corpus with ~20% exact twins so the duplicate path is hot.
    let g = CorpusGenerator::new(GeneratorConfig::short());
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 5 == 4 {
            let prev = docs[i as usize - 3].clone();
            docs.push(Doc { id: i, ..prev });
        } else {
            docs.push(g.generate(0xE17, i));
        }
    }

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        p_effective: 1e-10,
        expected_docs: n as u64,
        ..Default::default()
    };

    let mut results: Vec<Value> = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let mutex = run_mutex_coarse(&docs, threads, &cfg);
        let fine = run_mutex_fine(&docs, threads, &cfg);
        let engine = run_engine(&docs, threads, &cfg);
        println!(
            "{:<44} {:>12}/s",
            format!("ingest/mutex/threads={threads}"),
            fmt_count(mutex)
        );
        println!(
            "{:<44} {:>12}/s",
            format!("ingest/mutex-prepare-out/threads={threads}"),
            fmt_count(fine)
        );
        println!(
            "{:<44} {:>12}/s   ({:.1}x vs mutex, {:.1}x vs prepare-out)",
            format!("ingest/engine/threads={threads}"),
            fmt_count(engine),
            engine / mutex,
            engine / fine
        );
        println!();
        results.push(obj(vec![
            ("threads", Value::u64(threads as u64)),
            ("mutex_docs_per_sec", Value::num(mutex)),
            ("mutex_prepare_out_docs_per_sec", Value::num(fine)),
            ("engine_docs_per_sec", Value::num(engine)),
            ("speedup_vs_mutex", Value::num(engine / mutex)),
        ]));
    }

    let summary = obj(vec![
        ("bench", Value::str("micro_engine")),
        ("docs", Value::u64(n as u64)),
        ("results", Value::Arr(results)),
    ]);
    println!("{}", summary.to_json());
}
