//! Bloom filter and index-structure op latency (§4.5's throughput story):
//! contiguous bit-array probes vs hashmap band-index inserts/queries.
//!
//! `cargo bench --bench micro_bloom`

use lshbloom::bloom::BloomFilter;
use lshbloom::index::lshbloom::{LshBloomConfig, LshBloomIndex};
use lshbloom::index::minhashlsh::MinHashLshIndex;
use lshbloom::index::BandIndex;
use lshbloom::minhash::LshParams;
use lshbloom::perf::bench::Bencher;
use lshbloom::rng::Xoshiro256pp;

fn main() {
    println!("# index-structure op latency: bloom bit arrays vs hashmap band index\n");
    let mut rng = Xoshiro256pp::seeded(0xB100);
    let mut b = Bencher::default();

    // Raw filter ops at three fill levels.
    for &n in &[100_000u64, 1_000_000] {
        let mut filter = BloomFilter::with_capacity(n, 1e-10);
        for _ in 0..n / 2 {
            filter.insert(rng.next_u64());
        }
        let mut k = 0u64;
        let r = b.run(&format!("bloom/insert/n={n}"), || {
            k = k.wrapping_add(0x9E37_79B9_7F4A_7C15);
            filter.insert(k)
        });
        println!("{}", r.report());
        let mut q = 0u64;
        let r = b.run(&format!("bloom/query/n={n}"), || {
            q = q.wrapping_add(0xDEAD_BEEF);
            filter.contains(q)
        });
        println!("{}", r.report());
    }
    println!();

    // Whole-index op latency on identical band-hash inputs (b=42).
    let lsh = LshParams { num_bands: 42, rows_per_band: 6 };
    let docs: Vec<Vec<u64>> = (0..50_000)
        .map(|_| (0..42).map(|_| rng.next_u64()).collect())
        .collect();

    let mut bloom_idx = LshBloomIndex::new(LshBloomConfig {
        lsh,
        p_effective: 1e-10,
        expected_docs: 100_000,
        blocked: false,
    });
    let mut hashmap_idx = MinHashLshIndex::new(42, 6);
    for d in &docs {
        bloom_idx.insert_if_new(d);
        hashmap_idx.insert_if_new(d);
    }

    let mut blocked_idx = LshBloomIndex::new(LshBloomConfig {
        lsh,
        p_effective: 1e-10,
        expected_docs: 100_000,
        blocked: true,
    });
    for d in &docs {
        blocked_idx.insert_if_new(d);
    }

    let mut i = 0usize;
    let bloom = b.run("index/insert_if_new/lshbloom(b=42)", || {
        i = (i + 1) % docs.len();
        bloom_idx.insert_if_new(&docs[i])
    });
    println!("{}", bloom.report());
    let mut bi = 0usize;
    let blocked = b.run("index/insert_if_new/lshbloom-blocked(b=42)", || {
        bi = (bi + 1) % docs.len();
        blocked_idx.insert_if_new(&docs[bi])
    });
    println!("{}", blocked.report());
    println!(
        "  -> blocked filter speedup over classic: {:.1}x",
        bloom.median_ns() / blocked.median_ns()
    );
    let mut j = 0usize;
    let hashmap = b.run("index/insert_if_new/minhashlsh(b=42)", || {
        j = (j + 1) % docs.len();
        hashmap_idx.insert_if_new(&docs[j])
    });
    println!("{}", hashmap.report());
    println!(
        "\n  -> lshbloom index op is {:.1}x faster than the hashmap index",
        hashmap.median_ns() / bloom.median_ns()
    );
    println!(
        "  -> disk: lshbloom {} vs minhashlsh {} ({:.1}x smaller)",
        bloom_idx.disk_bytes(),
        hashmap_idx.disk_bytes(),
        hashmap_idx.disk_bytes() as f64 / bloom_idx.disk_bytes() as f64
    );
}
