//! §4.4.1 micro-benchmark: band sum-hash implementations.
//!
//! Reproduces the paper's claim that replacing Python's software bigint
//! arithmetic with fixed-precision 128-bit native arithmetic makes the
//! band-hash routine "over 94% faster" (i.e. >16x). Rows:
//!
//!   pybigint-sim  — base-2^30 digit arithmetic, alloc per +=  (baseline)
//!   u128 mod N    — exact 128-bit accumulate + one modulo   (§4.4.1)
//!   wrapping u64  — N = 2^64 fast path (the pipeline hot path)
//!
//! `cargo bench --bench micro_bandhash`

use lshbloom::hash::band::{band_hash_mod_n, band_hash_wrapping};
use lshbloom::hash::pybigint::band_hash_pybigint;
use lshbloom::perf::bench::Bencher;
use lshbloom::rng::Xoshiro256pp;

fn main() {
    println!("# §4.4.1 — band hashing: python-bigint simulation vs fixed-precision\n");
    let mut rng = Xoshiro256pp::seeded(0x4411);
    const N: u64 = (1 << 61) - 1;

    for r in [6usize, 13, 64, 256] {
        let band: Vec<u64> = (0..r).map(|_| rng.next_u64()).collect();
        let mut b = Bencher::default().throughput(r as u64);
        let slow = b.run(&format!("bandhash/r={r}/pybigint-sim"), || {
            band_hash_pybigint(&band, N)
        });
        println!("{}", slow.report());
        let fast = b.run(&format!("bandhash/r={r}/u128-mod-n"), || {
            band_hash_mod_n(&band, N)
        });
        println!("{}", fast.report());
        let wrap = b.run(&format!("bandhash/r={r}/wrapping-u64"), || {
            band_hash_wrapping(&band)
        });
        println!("{}", wrap.report());

        let reduction = 1.0 - fast.median_ns() / slow.median_ns();
        println!(
            "  -> fixed-precision is {:.1}% faster than bigint at r={r} (paper: >94%)\n",
            reduction * 100.0
        );
    }
}
