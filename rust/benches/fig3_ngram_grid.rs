//! Figure 3: F1 heatmaps for DCLM and Dolma-Ngram over
//! (n-gram size × overlap threshold) on the tuning corpus.
//!
//! `cargo bench --bench fig3_ngram_grid`

use lshbloom::eval::experiments::{fig3_grids, Scale};
use lshbloom::eval::tuner::ranges;
use lshbloom::report::{heatmap, CsvWriter};
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let mut csv = CsvWriter::create(
        Path::new("reports/fig3_ngram_grid.csv"),
        &["method", "threshold", "ngram", "precision", "recall", "f1"],
    )
    .expect("csv");

    for (kind, pts) in fig3_grids(scale) {
        let rows: Vec<String> = ranges::THRESHOLDS.iter().map(|t| format!("T={t}")).collect();
        let cols: Vec<String> = ranges::NGRAMS.iter().map(|n| format!("n={n}")).collect();
        let mut grid = vec![vec![0.0; ranges::NGRAMS.len()]; ranges::THRESHOLDS.len()];
        for gp in &pts {
            let ri = ranges::THRESHOLDS.iter().position(|&t| t == gp.spec.threshold).unwrap();
            let ci = ranges::NGRAMS.iter().position(|&n| n == gp.spec.ngram).unwrap();
            grid[ri][ci] = gp.f1();
            csv.row_disp(&[
                kind.name().to_string(),
                gp.spec.threshold.to_string(),
                gp.spec.ngram.to_string(),
                format!("{:.4}", gp.result.confusion.precision()),
                format!("{:.4}", gp.result.confusion.recall()),
                format!("{:.4}", gp.f1()),
            ])
            .unwrap();
        }
        println!("{}", heatmap(&format!("Fig 3 — {} F1", kind.name()), &rows, &cols, &grid));
    }
    csv.finish().unwrap();
    println!("(paper: DCLM best at T=0.2/n=5, small n better; Dolma-Ngram weaker and flat)");
}
