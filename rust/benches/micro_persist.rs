//! Persistence overhead: mmap-backed vs heap-backed concurrent ingest,
//! and checkpoint/restore wall times.
//!
//! Three questions, one JSON summary line:
//!
//! * **Ingest tax** — same corpus, same engine, 8 threads: heap-backed
//!   `submit` vs mmap-backed (`new_persistent`). The mmap path's writes
//!   land in page cache, so the tax should be noise (~10%), which is
//!   what makes always-durable ingest a sane default.
//! * **Checkpoint wall** — msync + manifest for the live mmap engine,
//!   full copy + manifest for the heap engine.
//! * **Restore wall** — mmap re-attach vs heap reload.
//!
//! `cargo bench --bench micro_persist` (LSHBLOOM_BENCH_FAST=1 for CI).

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{CorpusGenerator, Doc, GeneratorConfig};
use lshbloom::engine::ConcurrentEngine;
use lshbloom::json::{obj, Value};
use lshbloom::perf::bench::{fmt_count, fmt_dur, time_once};
use std::path::PathBuf;

const THREADS: usize = 8;

fn ingest_docs_per_sec(engine: &ConcurrentEngine, docs: &[Doc]) -> f64 {
    let super_batch = (THREADS * 128).max(256);
    let batches: Vec<Vec<Doc>> = docs.chunks(super_batch).map(|c| c.to_vec()).collect();
    let (_, wall) = time_once(|| {
        for batch in batches {
            engine.submit(batch);
        }
    });
    docs.len() as f64 / wall.as_secs_f64()
}

fn main() {
    println!("# persistence: mmap-backed vs heap ingest, checkpoint/restore walls\n");
    let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n: usize = if fast { 600 } else { 6_000 };

    let g = CorpusGenerator::new(GeneratorConfig::short());
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 5 == 4 {
            let prev = docs[i as usize - 3].clone();
            docs.push(Doc { id: i, ..prev });
        } else {
            docs.push(g.generate(0x9E57, i));
        }
    }

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        p_effective: 1e-10,
        expected_docs: n as u64,
        workers: THREADS,
        ..Default::default()
    };

    let state: PathBuf =
        std::env::temp_dir().join(format!("lshbloom-micro-persist-{}", std::process::id()));
    std::fs::remove_dir_all(&state).ok();
    let snap: PathBuf = state.join("snapshot");

    // Ingest: heap vs mmap.
    let heap_engine = ConcurrentEngine::from_config(&cfg);
    let heap_rate = ingest_docs_per_sec(&heap_engine, &docs);
    let mmap_engine = ConcurrentEngine::new_persistent(&cfg, &state).expect("persistent engine");
    let mmap_rate = ingest_docs_per_sec(&mmap_engine, &docs);
    println!("{:<44} {:>12}/s", format!("ingest/heap/threads={THREADS}"), fmt_count(heap_rate));
    println!(
        "{:<44} {:>12}/s   ({:.1}% of heap)",
        format!("ingest/mmap/threads={THREADS}"),
        fmt_count(mmap_rate),
        100.0 * mmap_rate / heap_rate
    );

    // Checkpoint walls: live msync vs cold copy.
    let (_, live_ckpt) = time_once(|| mmap_engine.checkpoint(&state).expect("live checkpoint"));
    let (_, cold_ckpt) = time_once(|| heap_engine.checkpoint(&snap).expect("cold checkpoint"));
    println!(
        "{:<44} {:>12}",
        "checkpoint/live-msync",
        fmt_dur(live_ckpt)
    );
    println!("{:<44} {:>12}", "checkpoint/cold-copy", fmt_dur(cold_ckpt));

    // Restore walls: mmap re-attach vs heap reload (from the cold copy,
    // whose checksums are verified — the worst case).
    let (warm, warm_restore) =
        time_once(|| ConcurrentEngine::restore(&cfg, &state, true).expect("warm restore"));
    let (cold, cold_restore) =
        time_once(|| ConcurrentEngine::restore(&cfg, &snap, false).expect("cold restore"));
    println!("{:<44} {:>12}", "restore/mmap-reattach", fmt_dur(warm_restore));
    println!("{:<44} {:>12}", "restore/heap-reload+checksum", fmt_dur(cold_restore));
    assert_eq!(warm.stats(), mmap_engine.stats());
    assert_eq!(cold.stats(), heap_engine.stats());

    let summary = obj(vec![
        ("bench", Value::str("micro_persist")),
        ("docs", Value::u64(n as u64)),
        ("threads", Value::u64(THREADS as u64)),
        ("heap_docs_per_sec", Value::num(heap_rate)),
        ("mmap_docs_per_sec", Value::num(mmap_rate)),
        ("mmap_vs_heap", Value::num(mmap_rate / heap_rate)),
        ("checkpoint_live_ms", Value::num(live_ckpt.as_secs_f64() * 1e3)),
        ("checkpoint_cold_ms", Value::num(cold_ckpt.as_secs_f64() * 1e3)),
        ("restore_mmap_ms", Value::num(warm_restore.as_secs_f64() * 1e3)),
        ("restore_heap_ms", Value::num(cold_restore.as_secs_f64() * 1e3)),
    ]);
    println!("{}", summary.to_json());

    drop(warm);
    drop(mmap_engine);
    std::fs::remove_dir_all(&state).ok();
}
