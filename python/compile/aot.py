"""AOT entrypoint: lower the Layer-2 model to HLO-text artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits, per configuration in ``CONFIGS``:
  * ``minhash_bands_B{B}_L{L}_P{P}_T{T}.hlo.txt``  (fused hot path)
  * ``minhash_sigs_B{B}_L{L}_P{P}.hlo.txt``        (chunked-doc path, 1st half)
  * ``band_hashes_B{B}_P{P}_T{T}.hlo.txt``         (chunked-doc path, 2nd half)
plus ``manifest.json`` describing every artifact's static geometry and
``golden.json`` with cross-language test vectors that pin the rust native
backend to these kernels bit-for-bit.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.common import PAD_SENTINEL, splitmix64_stream  # noqa: E402
from .kernels.ref import minhash_bands_ref, minhash_signatures_ref  # noqa: E402
from .lsh_params import optimal_param  # noqa: E402

# Master seed for the permutation-seed stream; rust mirrors this constant
# (rust/src/minhash/signature.rs::PERM_MASTER_SEED).
PERM_MASTER_SEED = 0x5348426C6F6F6D  # b"SHBloom"

# (batch, max tokens per row, permutations, similarity threshold)
# - the "main" config is the pipeline default (T=0.5, P=256, Table 1);
# - the "tune" config covers the paper's T=0.8/P=128 example (9 bands);
# - the "test" config is tiny so runtime unit tests compile fast.
CONFIGS = [
    {"name": "main", "B": 64, "L": 512, "P": 256, "T": 0.5},
    {"name": "tune", "B": 64, "L": 512, "P": 128, "T": 0.8},
    {"name": "test", "B": 8, "L": 128, "P": 128, "T": 0.5},
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg, out_dir):
    num_docs, length, num_perms, threshold = cfg["B"], cfg["L"], cfg["P"], cfg["T"]
    num_bands, rows_per_band = optimal_param(threshold, num_perms)

    tok_spec = jax.ShapeDtypeStruct((num_docs, length), jnp.uint64)
    seed_spec = jax.ShapeDtypeStruct((num_perms,), jnp.uint64)
    sig_spec = jax.ShapeDtypeStruct((num_docs, num_perms), jnp.uint64)

    entries = []

    fused = jax.jit(model.fused_fn(num_bands, rows_per_band))
    name = f"minhash_bands_B{num_docs}_L{length}_P{num_perms}_T{threshold}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(fused.lower(tok_spec, seed_spec)))
    entries.append(
        {
            "kind": "minhash_bands",
            "file": os.path.basename(path),
            "B": num_docs,
            "L": length,
            "P": num_perms,
            "threshold": threshold,
            "num_bands": num_bands,
            "rows_per_band": rows_per_band,
        }
    )

    sigs = jax.jit(model.minhash_signatures)
    name = f"minhash_sigs_B{num_docs}_L{length}_P{num_perms}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(sigs.lower(tok_spec, seed_spec)))
    entries.append(
        {
            "kind": "minhash_sigs",
            "file": os.path.basename(path),
            "B": num_docs,
            "L": length,
            "P": num_perms,
        }
    )

    bands = jax.jit(
        lambda s: model.band_hashes(
            s, num_bands=num_bands, rows_per_band=rows_per_band
        )
    )
    name = f"band_hashes_B{num_docs}_P{num_perms}_T{threshold}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(bands.lower(sig_spec)))
    entries.append(
        {
            "kind": "band_hashes",
            "file": os.path.basename(path),
            "B": num_docs,
            "P": num_perms,
            "threshold": threshold,
            "num_bands": num_bands,
            "rows_per_band": rows_per_band,
        }
    )
    return entries


def golden_vectors():
    """Small deterministic vectors pinning python<->rust equivalence."""
    num_docs, length, num_perms = 4, 16, 8
    num_bands, rows_per_band = 4, 2
    seeds = splitmix64_stream(PERM_MASTER_SEED, num_perms)
    # Deterministic token hashes, including padded rows.
    toks = splitmix64_stream(0xC0FFEE, num_docs * length).reshape(num_docs, length)
    toks = toks.at[1, 10:].set(jnp.uint64(PAD_SENTINEL))  # partially padded row
    toks = toks.at[3, :].set(jnp.uint64(PAD_SENTINEL))  # fully padded row
    toks = toks.at[2, :].set(toks[0, :])  # duplicate of row 0
    sigs = minhash_signatures_ref(toks, seeds)
    bands = minhash_bands_ref(toks, seeds, num_bands, rows_per_band)
    return {
        "perm_master_seed": str(PERM_MASTER_SEED),
        "B": num_docs,
        "L": length,
        "P": num_perms,
        "num_bands": num_bands,
        "rows_per_band": rows_per_band,
        "seeds": [str(int(x)) for x in seeds],
        "tokens": [[str(int(x)) for x in row] for row in toks],
        "signatures": [[str(int(x)) for x in row] for row in sigs],
        "band_hashes": [[str(int(x)) for x in row] for row in bands],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"configs": []}
    for cfg in CONFIGS:
        entries = lower_config(cfg, args.out_dir)
        manifest["configs"].append({"name": cfg["name"], "artifacts": entries})
        print(f"lowered config {cfg['name']}: {[e['file'] for e in entries]}")

    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(golden_vectors(), f, indent=1)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest + golden vectors to {args.out_dir}")


if __name__ == "__main__":
    main()
