"""Build-time compile package: Layer-2 JAX model + Layer-1 Pallas kernels.

Nothing in this package runs at request time; `aot.py` lowers the model to
HLO text artifacts that the rust coordinator loads through PJRT.
"""

import jax

# The whole stack works on u64 token hashes / signatures; enable x64 before
# any tracing happens anywhere in this package.
jax.config.update("jax_enable_x64", True)
