"""Shared constants + the mix64 permutation family.

The permutation family must match `rust/src/hash/mix64.rs` bit-for-bit:
``perm_i(h) = mix64(h XOR seed_i)`` where ``mix64`` is the splitmix64
finalizer (Vigna).  All arithmetic is wrapping u64, which both XLA and
rust implement natively (see DESIGN.md "Deviation: permutation family"
for why the datasketch `(a*h+b) mod 2^61-1` family is not XLA-expressible
without 128-bit intermediates).
"""

import jax.numpy as jnp

# splitmix64 finalizer multipliers (Vigna / Stafford mix13).
MIX64_M1 = 0xBF58476D1CE4E5B9
MIX64_M2 = 0x94D049BB133111EB

# Token rows are padded to the static length L with this sentinel; the
# kernel maps sentinel lanes to u64::MAX so they never win the min-reduce.
PAD_SENTINEL = 0xFFFF_FFFF_FFFF_FFFF

U64_MAX = 0xFFFF_FFFF_FFFF_FFFF


def mix64(z):
    """splitmix64 finalizer over a u64 array (wrapping arithmetic)."""
    z = jnp.asarray(z, dtype=jnp.uint64)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(MIX64_M1)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(MIX64_M2)
    return z ^ (z >> jnp.uint64(31))


def splitmix64_stream(seed: int, n: int):
    """First ``n`` outputs of the splitmix64 generator seeded with ``seed``.

    Matches ``rust/src/rng.rs::SplitMix64`` exactly: state advances by the
    golden-gamma constant and each output is the finalizer of the new state.
    Used to derive the per-permutation seeds on both sides of the bridge.
    """
    golden = 0x9E3779B97F4A7C15
    out = []
    state = seed & U64_MAX
    for _ in range(n):
        state = (state + golden) & U64_MAX
        z = state
        z = ((z ^ (z >> 30)) * MIX64_M1) & U64_MAX
        z = ((z ^ (z >> 27)) * MIX64_M2) & U64_MAX
        z = z ^ (z >> 31)
        out.append(z)
    return jnp.array(out, dtype=jnp.uint64)
