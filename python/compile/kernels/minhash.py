"""Layer-1 Pallas kernel: MinHash signatures.

Computes ``sig[d, p] = min over valid tokens t of mix64(tokens[d, t] ^ seeds[p])``
for a block-tiled grid over documents and permutations.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):

* Output is tiled ``(BLOCK_B, BLOCK_P)``; each program instance owns one
  tile of the signature matrix.
* The token axis is *streamed*: an inner ``fori_loop`` walks L in
  ``CHUNK_L``-sized slabs so the live intermediate is
  ``(BLOCK_B, BLOCK_P, CHUNK_L)`` — with the defaults (8, 128, 128) that is
  1 MiB of u64, comfortably inside VMEM, instead of materializing the full
  ``(B, P, L)`` cube like the reference oracle.
* Integer-only VPU work; the MXU is structurally idle (no matmul).

``interpret=True`` is mandatory on this CPU testbed: real-TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import MIX64_M1, MIX64_M2, PAD_SENTINEL, U64_MAX

# Default tile sizes; BLOCK_P is the lane-dim multiple of the VPU (128),
# BLOCK_B trades grid size against VMEM (8*128 u64 accumulator = 8 KiB).
BLOCK_B = 8
BLOCK_P = 128
CHUNK_L = 128


def _mix64_u64(z):
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(MIX64_M1)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(MIX64_M2)
    return z ^ (z >> jnp.uint64(31))


def _minhash_kernel(tokens_ref, seeds_ref, out_ref, *, chunk_l: int):
    """One (BLOCK_B, BLOCK_P) signature tile; streams tokens in chunks."""
    toks = tokens_ref[...]  # (BLOCK_B, L)
    seeds = seeds_ref[...]  # (BLOCK_P,)
    block_b, length = toks.shape
    block_p = seeds.shape[0]
    num_chunks = length // chunk_l  # L is padded to a CHUNK_L multiple

    def body(c, acc):
        sl = jax.lax.dynamic_slice(toks, (0, c * chunk_l), (block_b, chunk_l))
        # (BLOCK_B, 1, CHUNK_L) ^ (1, BLOCK_P, 1) -> (BLOCK_B, BLOCK_P, CHUNK_L)
        mixed = _mix64_u64(sl[:, None, :] ^ seeds[None, :, None])
        valid = sl[:, None, :] != jnp.uint64(PAD_SENTINEL)
        masked = jnp.where(valid, mixed, jnp.uint64(U64_MAX))
        return jnp.minimum(acc, masked.min(axis=2))

    init = jnp.full((block_b, block_p), U64_MAX, dtype=jnp.uint64)
    out_ref[...] = jax.lax.fori_loop(0, num_chunks, body, init)


def minhash_signatures(
    tokens,
    seeds,
    *,
    block_b: int = BLOCK_B,
    block_p: int = BLOCK_P,
    chunk_l: int = CHUNK_L,
):
    """Pallas MinHash signatures: u64[B, L] x u64[P] -> u64[B, P].

    B must be a multiple of ``block_b``, P of ``block_p``, and L of
    ``chunk_l`` (the rust marshaller pads all three with PAD_SENTINEL /
    duplicate seeds as needed).
    """
    tokens = jnp.asarray(tokens, dtype=jnp.uint64)
    seeds = jnp.asarray(seeds, dtype=jnp.uint64)
    num_docs, length = tokens.shape
    num_perms = seeds.shape[0]
    if num_docs % block_b:
        raise ValueError(f"B={num_docs} not a multiple of block_b={block_b}")
    if num_perms % block_p:
        raise ValueError(f"P={num_perms} not a multiple of block_p={block_p}")
    if length % chunk_l:
        raise ValueError(f"L={length} not a multiple of chunk_l={chunk_l}")

    grid = (num_docs // block_b, num_perms // block_p)
    return pl.pallas_call(
        functools.partial(_minhash_kernel, chunk_l=chunk_l),
        grid=grid,
        in_specs=[
            # Each tile sees its document rows and the full token axis.
            pl.BlockSpec((block_b, length), lambda i, j: (i, 0)),
            # And its slice of the permutation seeds.
            pl.BlockSpec((block_p,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_b, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((num_docs, num_perms), jnp.uint64),
        interpret=True,
    )(tokens, seeds)
