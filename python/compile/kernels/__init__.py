"""Layer-1 Pallas kernels for LSHBloom's MinHash hot path.

Exports:
  minhash.minhash_signatures  -- pallas kernel: token hashes -> signatures
  bandhash.band_hashes        -- pallas kernel: signatures -> band sum-hashes
  ref                         -- pure-jnp oracles used by pytest
"""
