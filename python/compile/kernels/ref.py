"""Pure-jnp oracles for the Pallas kernels.

These are the "obviously correct" reference implementations that pytest
compares the kernels against.  They also define the semantics that the
native rust backend (`rust/src/minhash/signature.rs`) mirrors bit-for-bit
via golden vectors (`artifacts/golden.json`).
"""

import jax.numpy as jnp

from .common import PAD_SENTINEL, U64_MAX, mix64


def minhash_signatures_ref(tokens, seeds):
    """MinHash signature matrix.

    Args:
      tokens: u64[B, L] token hashes, padded with ``PAD_SENTINEL``.
      seeds:  u64[P] per-permutation seeds.

    Returns:
      u64[B, P]: ``sig[d, p] = min over valid tokens t of mix64(t ^ seeds[p])``.
      A row with no valid token yields ``U64_MAX``.
    """
    tokens = jnp.asarray(tokens, dtype=jnp.uint64)
    seeds = jnp.asarray(seeds, dtype=jnp.uint64)
    # (B, 1, L) ^ (1, P, 1) -> (B, P, L)
    mixed = mix64(tokens[:, None, :] ^ seeds[None, :, None])
    valid = tokens[:, None, :] != jnp.uint64(PAD_SENTINEL)
    masked = jnp.where(valid, mixed, jnp.uint64(U64_MAX))
    return masked.min(axis=2)


def band_hashes_ref(sigs, num_bands, rows_per_band):
    """Band sum-hashes (paper §4.1): ``h(band) = (sum_i sig_i) mod 2^64``.

    Uses only the first ``num_bands * rows_per_band`` signature rows (the
    datasketch convention when b*r < P).

    Args:
      sigs: u64[B, P] signature matrix.

    Returns:
      u64[B, num_bands] wrapping sums per band.
    """
    sigs = jnp.asarray(sigs, dtype=jnp.uint64)
    used = sigs[:, : num_bands * rows_per_band]
    grouped = used.reshape(sigs.shape[0], num_bands, rows_per_band)
    # uint64 addition wraps in XLA == sum mod 2^64 (N = 2^64 in §4.1).
    return grouped.sum(axis=2, dtype=jnp.uint64)


def minhash_bands_ref(tokens, seeds, num_bands, rows_per_band):
    """Fused oracle: token hashes -> band hashes."""
    return band_hashes_ref(
        minhash_signatures_ref(tokens, seeds), num_bands, rows_per_band
    )
