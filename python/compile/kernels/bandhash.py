"""Layer-1 Pallas kernel: band sum-hashes (paper §4.1).

Reduces each band of ``rows_per_band`` MinHash values to a single u64 via
a wrapping sum — i.e. ``(sum_i h_i) mod N`` with ``N = 2^64``, which makes
the modulo free and the band-collision term ``b/N`` negligible (§4.3).

This is the operation §4.4.1 of the paper ports from Python bigints to
fixed-precision native arithmetic; here it is data-parallel over the
whole signature batch.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 8


def _bandhash_kernel(sigs_ref, out_ref, *, num_bands: int, rows_per_band: int):
    sigs = sigs_ref[...]  # (BLOCK_B, P)
    block_b = sigs.shape[0]
    used = sigs[:, : num_bands * rows_per_band]
    grouped = used.reshape(block_b, num_bands, rows_per_band)
    out_ref[...] = grouped.sum(axis=2, dtype=jnp.uint64)


def band_hashes(sigs, num_bands: int, rows_per_band: int, *, block_b: int = BLOCK_B):
    """Pallas band hashes: u64[B, P] -> u64[B, num_bands].

    Requires ``num_bands * rows_per_band <= P`` (datasketch convention:
    leftover signature rows are unused) and B a multiple of ``block_b``.
    """
    sigs = jnp.asarray(sigs, dtype=jnp.uint64)
    num_docs, num_perms = sigs.shape
    if num_bands * rows_per_band > num_perms:
        raise ValueError(
            f"b*r = {num_bands}*{rows_per_band} exceeds P={num_perms}"
        )
    if num_docs % block_b:
        raise ValueError(f"B={num_docs} not a multiple of block_b={block_b}")

    grid = (num_docs // block_b,)
    return pl.pallas_call(
        functools.partial(
            _bandhash_kernel, num_bands=num_bands, rows_per_band=rows_per_band
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, num_perms), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, num_bands), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_docs, num_bands), jnp.uint64),
        interpret=True,
    )(sigs)
