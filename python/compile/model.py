"""Layer-2 JAX model: the batched MinHash → band-hash compute graph.

This is the unit the rust coordinator executes per document batch on its
ingest path: token hashes in, band hashes out.  It composes the two
Layer-1 Pallas kernels so that a single fused HLO module is produced at
AOT time.

Variants:
  * ``minhash_bands``      — fused tokens -> band hashes (the hot path).
  * ``minhash_signatures`` — tokens -> full signature matrix (used when the
    coordinator min-combines chunked long documents before band hashing).
  * ``band_hashes``        — signatures -> band hashes (second half of the
    chunked path).
"""

import functools

from .kernels import bandhash as bandhash_kernel
from .kernels import minhash as minhash_kernel


def minhash_signatures(tokens, seeds):
    """u64[B, L] x u64[P] -> u64[B, P] (Pallas kernel, tiled)."""
    return minhash_kernel.minhash_signatures(tokens, seeds)


def band_hashes(sigs, *, num_bands: int, rows_per_band: int):
    """u64[B, P] -> u64[B, b] (Pallas kernel)."""
    return bandhash_kernel.band_hashes(sigs, num_bands, rows_per_band)


def minhash_bands(tokens, seeds, *, num_bands: int, rows_per_band: int):
    """Fused hot path: u64[B, L] x u64[P] -> u64[B, b]."""
    sigs = minhash_signatures(tokens, seeds)
    return band_hashes(sigs, num_bands=num_bands, rows_per_band=rows_per_band)


def fused_fn(num_bands: int, rows_per_band: int):
    """A jit-lowerable callable for AOT export (static band geometry)."""
    return functools.partial(
        minhash_bands, num_bands=num_bands, rows_per_band=rows_per_band
    )
