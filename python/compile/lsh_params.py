"""Optimal LSH band parameters (b, r) — datasketch/Zhu-et-al. procedure.

Minimizes ``w_fp * FP_lsh(b, r) + w_fn * FN_lsh(b, r)`` over all integer
(b, r) with ``b * r <= num_perm``, where FP/FN are the paper's Eqs. (1)/(2)
evaluated by rectangle-rule integration with dx = 0.001.

This module MUST stay in lock-step with ``rust/src/minhash/params.rs``:
both sides compute (b, r) independently (python at AOT time to fix the
band-hash artifact's static shape, rust at run time) and the golden
manifest pins them against each other.
"""

_INTEGRATION_DX = 0.001


def _integrate(f, a: float, b: float) -> float:
    """Midpoint rectangle rule, dx=0.001 (matches datasketch._integration)."""
    area = 0.0
    x = a
    while x < b:
        area += f(x + 0.5 * _INTEGRATION_DX) * _INTEGRATION_DX
        x += _INTEGRATION_DX
    return area


def false_positive_probability(threshold: float, b: int, r: int) -> float:
    """Paper Eq. (1): integral over [0, T] of 1 - (1 - t^r)^b."""
    return _integrate(lambda t: 1.0 - (1.0 - t**r) ** b, 0.0, threshold)


def false_negative_probability(threshold: float, b: int, r: int) -> float:
    """Paper Eq. (2): integral over [T, 1] of (1 - t^r)^b."""
    return _integrate(lambda t: (1.0 - t**r) ** b, threshold, 1.0)


def optimal_param(
    threshold: float,
    num_perm: int,
    fp_weight: float = 0.5,
    fn_weight: float = 0.5,
):
    """Best (b, r) minimizing the weighted FP/FN error.

    Returns:
      (b, r): the argmin over b in [1, num_perm], r in [1, num_perm // b].
    """
    best = (float("inf"), 1, 1)
    for b in range(1, num_perm + 1):
        max_r = num_perm // b
        for r in range(1, max_r + 1):
            err = fp_weight * false_positive_probability(
                threshold, b, r
            ) + fn_weight * false_negative_probability(threshold, b, r)
            if err < best[0]:
                best = (err, b, r)
    return best[1], best[2]
