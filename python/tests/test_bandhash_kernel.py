"""Layer-1 Pallas bandhash kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bandhash, ref
from compile.kernels.common import splitmix64_stream


@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    p=st.sampled_from([128, 256]),
    geometry=st.sampled_from([(9, 13), (25, 5), (42, 6), (1, 1), (4, 32)]),
    seed=st.integers(0, 2**32 - 1),
)
def test_bandhash_sweep(b_blocks, p, geometry, seed):
    num_bands, rows = geometry
    if num_bands * rows > p:
        return  # geometry must fit P
    B = 8 * b_blocks
    sigs = splitmix64_stream(seed, B * p).reshape(B, p)
    got = bandhash.band_hashes(sigs, num_bands, rows)
    want = ref.band_hashes_ref(sigs, num_bands, rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wrapping_sums_explicitly():
    # Two values that overflow u64: (2^64-1) + 1 wraps to 0.
    sigs = jnp.array([[jnp.uint64(2**64 - 1), jnp.uint64(1)]] * 8, dtype=jnp.uint64)
    got = np.asarray(bandhash.band_hashes(sigs, 1, 2))
    assert (got == 0).all()


def test_leftover_rows_are_ignored():
    # b*r < P: trailing signature rows must not affect band hashes.
    sigs = splitmix64_stream(5, 8 * 128).reshape(8, 128)
    tweaked = sigs.at[:, 125:].set(jnp.uint64(0))
    a = np.asarray(bandhash.band_hashes(sigs, 25, 5))  # uses rows 0..125
    b = np.asarray(bandhash.band_hashes(tweaked, 25, 5))
    np.testing.assert_array_equal(a, b)


def test_rejects_oversized_geometry():
    sigs = splitmix64_stream(1, 8 * 128).reshape(8, 128)
    with pytest.raises(ValueError):
        bandhash.band_hashes(sigs, 26, 5)  # 130 > 128
