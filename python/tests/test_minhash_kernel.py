"""Layer-1 Pallas minhash kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and data; every case asserts exact (integer)
equality — there is no tolerance in this pipeline, signatures must be
bit-identical across kernel, oracle, and the rust backends.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import minhash, ref
from compile.kernels.common import PAD_SENTINEL, U64_MAX, mix64, splitmix64_stream


def make_tokens(rows, cols, seed, pad_tail=None):
    toks = splitmix64_stream(seed, rows * cols).reshape(rows, cols)
    if pad_tail:
        for row, keep in pad_tail:
            toks = toks.at[row, keep:].set(jnp.uint64(PAD_SENTINEL))
    return toks


class TestMix64:
    def test_matches_rust_reference_vector(self):
        # Pinned against rust's splitmix64 tests (seed=0 stream).
        s = splitmix64_stream(0, 3)
        assert int(s[0]) == 0xE220A8397B1DCDAF
        assert int(s[1]) == 0x6E789E6AA1B965F4
        assert int(s[2]) == 0x06C45D188009454F

    def test_mix64_is_deterministic_and_nontrivial(self):
        xs = jnp.arange(16, dtype=jnp.uint64)
        a = mix64(xs)
        b = mix64(xs)
        assert (a == b).all()
        assert len(set(int(v) for v in a)) == 16


class TestMinhashKernelVsRef:
    @settings(max_examples=20, deadline=None)
    @given(
        b_blocks=st.integers(1, 3),
        p_blocks=st.integers(1, 2),
        l_chunks=st.integers(1, 3),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_shapes_sweep_exact_equality(self, b_blocks, p_blocks, l_chunks, seed):
        B, P, L = 8 * b_blocks, 128 * p_blocks, 128 * l_chunks
        toks = make_tokens(B, L, seed)
        seeds = splitmix64_stream(seed ^ 0xABCDEF, P)
        got = minhash.minhash_signatures(toks, seeds)
        want = ref.minhash_signatures_ref(toks, seeds)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**32 - 1),
        pad_rows=st.lists(
            st.tuples(st.integers(0, 7), st.integers(0, 128)), max_size=4
        ),
    )
    def test_padding_sweep(self, seed, pad_rows):
        toks = make_tokens(8, 128, seed, pad_tail=pad_rows)
        seeds = splitmix64_stream(seed + 1, 128)
        got = minhash.minhash_signatures(toks, seeds)
        want = ref.minhash_signatures_ref(toks, seeds)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fully_padded_row_yields_u64max(self):
        toks = jnp.full((8, 128), PAD_SENTINEL, dtype=jnp.uint64)
        seeds = splitmix64_stream(7, 128)
        got = minhash.minhash_signatures(toks, seeds)
        assert (np.asarray(got) == np.uint64(U64_MAX)).all()

    def test_duplicate_rows_get_identical_signatures(self):
        toks = make_tokens(8, 128, 99)
        toks = toks.at[3].set(toks[0])
        seeds = splitmix64_stream(5, 128)
        got = np.asarray(minhash.minhash_signatures(toks, seeds))
        np.testing.assert_array_equal(got[0], got[3])

    def test_signature_is_permutation_invariant_over_tokens(self):
        # MinHash is a set operation: shuffling the token axis must not
        # change signatures.
        toks = make_tokens(8, 128, 31)
        perm = np.random.RandomState(0).permutation(128)
        shuffled = jnp.asarray(np.asarray(toks)[:, perm])
        seeds = splitmix64_stream(11, 128)
        a = minhash.minhash_signatures(toks, seeds)
        b = minhash.minhash_signatures(shuffled, seeds)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rejects_bad_shapes(self):
        seeds = splitmix64_stream(1, 128)
        with pytest.raises(ValueError):
            minhash.minhash_signatures(jnp.zeros((7, 128), jnp.uint64), seeds)
        with pytest.raises(ValueError):
            minhash.minhash_signatures(jnp.zeros((8, 100), jnp.uint64), seeds)
        with pytest.raises(ValueError):
            minhash.minhash_signatures(
                jnp.zeros((8, 128), jnp.uint64), splitmix64_stream(1, 100)
            )

    def test_block_shape_ablation_identical_results(self):
        # Different tile geometries must not change the numerics.
        toks = make_tokens(16, 256, 77)
        seeds = splitmix64_stream(13, 128)
        base = np.asarray(minhash.minhash_signatures(toks, seeds))
        for block_b, chunk_l in [(8, 128), (16, 256), (8, 256), (16, 128)]:
            alt = np.asarray(
                minhash.minhash_signatures(
                    toks, seeds, block_b=block_b, chunk_l=chunk_l
                )
            )
            np.testing.assert_array_equal(base, alt, err_msg=f"{block_b}/{chunk_l}")
