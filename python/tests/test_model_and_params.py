"""Layer-2 model composition, LSH parameter optimizer, and AOT artifacts."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.common import splitmix64_stream
from compile.lsh_params import (
    false_negative_probability,
    false_positive_probability,
    optimal_param,
)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLshParams:
    def test_paper_example(self):
        # §4.5: T=0.8, 128 perms -> nine bands (r=13).
        assert optimal_param(0.8, 128) == (9, 13)

    def test_main_config(self):
        assert optimal_param(0.5, 256) == (42, 6)
        assert optimal_param(0.5, 128) == (25, 5)

    @settings(max_examples=15, deadline=None)
    @given(
        t=st.sampled_from([0.2, 0.4, 0.5, 0.6, 0.8]),
        p=st.sampled_from([32, 48, 64, 128]),
    )
    def test_geometry_fits(self, t, p):
        b, r = optimal_param(t, p)
        assert 1 <= b and 1 <= r and b * r <= p

    def test_integral_monotonicity(self):
        # More bands -> FP mass up, FN mass down.
        assert false_positive_probability(0.5, 16, 8) > false_positive_probability(0.5, 4, 8)
        assert false_negative_probability(0.5, 16, 8) < false_negative_probability(0.5, 4, 8)


class TestModel:
    def test_fused_equals_composition(self):
        toks = splitmix64_stream(3, 8 * 128).reshape(8, 128)
        seeds = splitmix64_stream(4, 128)
        fused = model.minhash_bands(toks, seeds, num_bands=25, rows_per_band=5)
        sigs = model.minhash_signatures(toks, seeds)
        bands = model.band_hashes(sigs, num_bands=25, rows_per_band=5)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(bands))
        want = ref.minhash_bands_ref(toks, seeds, 25, 5)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))

    def test_jit_lowering_shapes(self):
        fn = jax.jit(model.fused_fn(25, 5))
        tok_spec = jax.ShapeDtypeStruct((8, 128), jnp.uint64)
        seed_spec = jax.ShapeDtypeStruct((128,), jnp.uint64)
        lowered = fn.lower(tok_spec, seed_spec)
        hlo = lowered.compiler_ir("stablehlo")
        text = str(hlo)
        assert "8x25" in text.replace("tensor<", ""), "output shape missing"


class TestArtifacts:
    def test_manifest_exists_and_is_consistent(self):
        path = os.path.join(ARTIFACTS, "manifest.json")
        if not os.path.exists(path):
            import pytest

            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            manifest = json.load(f)
        for cfg in manifest["configs"]:
            for art in cfg["artifacts"]:
                # Every artifact file must exist and be non-trivial HLO text.
                fp = os.path.join(ARTIFACTS, art["file"])
                assert os.path.exists(fp), art["file"]
                head = open(fp).read(200)
                assert "HloModule" in head, f"{art['file']} is not HLO text"
                # Band geometry in the manifest must match the optimizer.
                if "num_bands" in art:
                    b, r = optimal_param(art["threshold"], art["P"])
                    assert (b, r) == (art["num_bands"], art["rows_per_band"])

    def test_golden_vectors_reproduce(self):
        path = os.path.join(ARTIFACTS, "golden.json")
        if not os.path.exists(path):
            import pytest

            pytest.skip("artifacts not built (run `make artifacts`)")
        from compile.aot import golden_vectors

        with open(path) as f:
            on_disk = json.load(f)
        fresh = golden_vectors()
        assert on_disk == fresh, "golden vectors drifted from the oracle"
