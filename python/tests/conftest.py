"""Shared pytest fixtures for the compile-path test suite."""

import os
import sys

# Make `compile` importable when pytest runs from python/ or the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

jax.config.update("jax_enable_x64", True)
