//! Continuous ingestion with backpressure and a persistent /dev/shm index.
//!
//! ```bash
//! cargo run --release --example streaming_ingest
//! ```
//!
//! Models the paper's deployment story (§4.4.2 + §1): a corpus arrives in
//! waves (e.g. monthly CommonCrawl drops); the LSHBloom index persists
//! between waves so previously ingested content stays deduplicated, and
//! re-parsed versions of old documents are caught as duplicates.

use lshbloom::corpus::stream::StreamSpec;
use lshbloom::hash::band::band_hashes_for_doc;
use lshbloom::index::lshbloom::{LshBloomConfig, LshBloomIndex};
use lshbloom::index::BandIndex;
use lshbloom::minhash::{optimal_param, MinHasher, PermFamily};
use lshbloom::report::table::{bytes, Table};
use lshbloom::text::normalize;
use std::time::Instant;

fn main() {
    let work_dir = std::env::temp_dir().join(format!("lshbloom-ingest-{}", std::process::id()));
    std::fs::create_dir_all(&work_dir).unwrap();
    let index_dir = work_dir.join("index");

    let lsh = optimal_param(0.5, 256);
    let hasher = MinHasher::new(PermFamily::Mix64, lsh.rows_used(), 1);
    let expected_docs = 100_000; // plan capacity across ALL waves upfront

    let mut summary = Table::new(
        "streaming ingest across waves",
        &["wave", "docs", "new", "dups", "wall (s)", "index disk", "filter fill"],
    );

    for wave in 0..3u64 {
        // Load (or create) the persistent index.
        let mut index = if index_dir.join("meta.json").exists() {
            LshBloomIndex::load_dir(&index_dir).expect("reload index")
        } else {
            LshBloomIndex::new(LshBloomConfig {
                lsh,
                p_effective: 1e-10,
                expected_docs,
                blocked: false,
            })
        };
        let already = index.len();

        // A new wave of documents; later waves overlap earlier ones
        // because the stream seed is shared (re-scraped content).
        let spec = StreamSpec { dup_rate: 0.25, ..StreamSpec::pes2o_sim(7, 4_000 + wave * 1000) };
        let t0 = Instant::now();
        let mut bands = Vec::new();
        let (mut new_docs, mut dups, mut seen) = (0u64, 0u64, 0u64);
        for ld in spec.stream().skip((wave * 2000) as usize) {
            seen += 1;
            let sig = hasher.signature(&normalize(&ld.doc.text));
            band_hashes_for_doc(&sig, lsh.num_bands, lsh.rows_per_band, &mut bands);
            if index.insert_if_new(&bands) {
                dups += 1;
            } else {
                new_docs += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        let fill = index.fill_ratios().iter().copied().fold(0.0f64, f64::max);
        summary.row_disp(&[
            format!("{wave} (resumed at {already})"),
            seen.to_string(),
            new_docs.to_string(),
            dups.to_string(),
            format!("{wall:.2}"),
            bytes(index.disk_bytes()),
            format!("{:.4}", fill),
        ]);

        index.save_dir(&index_dir).expect("persist index");
    }

    summary.print();
    println!("index persisted at {}", index_dir.display());
    std::fs::remove_dir_all(&work_dir).ok();
    println!("ok");
}
