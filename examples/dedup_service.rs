//! Network deduplication service demo: spawn the TCP service, drive it
//! from three concurrent ingestion clients, print shared-index stats.
//!
//! ```bash
//! cargo run --release --example dedup_service
//! ```
//!
//! In production the server runs standalone (`lshbloom serve`) and
//! scraper/parser fleets connect as clients; here everything lives in
//! one process for a self-contained demo.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::stream::StreamSpec;
use lshbloom::service::{DedupClient, DedupServer};

fn main() {
    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 256,
        p_effective: 1e-10,
        expected_docs: 100_000,
        blocked_bloom: true, // §Perf: fast inserts for a live service
        ..Default::default()
    };
    let server = DedupServer::bind("127.0.0.1:0", &cfg).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    println!("service on {addr}");
    let server_thread = std::thread::spawn(move || server.serve().expect("serve"));

    // Three ingestion workers, each feeding a slice of the same stream
    // (with overlap, as re-scraped content produces).
    let mut workers = Vec::new();
    for w in 0..3u64 {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut client = DedupClient::connect(&addr).expect("connect");
            let spec = StreamSpec { dup_rate: 0.3, ..StreamSpec::pes2o_sim(7, 1200) };
            let mut dups = 0u64;
            // Overlapping windows: worker w takes docs [w*300, w*300+600).
            for ld in spec.stream().skip((w * 300) as usize).take(600) {
                if client.check(&ld.doc.text).expect("check") {
                    dups += 1;
                }
            }
            (w, dups)
        }));
    }
    for h in workers {
        let (w, dups) = h.join().unwrap();
        println!("worker {w}: {dups} duplicates flagged");
    }

    let mut client = DedupClient::connect(&addr).unwrap();
    let (docs, dups, disk) = client.stats().unwrap();
    println!("\nshared index: {docs} docs, {dups} duplicates, {disk} bytes");
    assert_eq!(docs, 1800);
    // Overlapping windows guarantee plenty of cross-worker duplicates.
    assert!(dups > 400, "expected cross-worker duplicates, got {dups}");
    client.shutdown().unwrap();
    server_thread.join().unwrap();
    println!("ok");
}
