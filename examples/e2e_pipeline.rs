//! End-to-end validation driver (recorded in EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example e2e_pipeline            # 200k docs
//! LSHBLOOM_E2E_DOCS=20000 cargo run --release --example e2e_pipeline
//! ```
//!
//! Exercises the *full three-layer stack* on a peS2o-sim workload:
//!
//! 1. Layer 1+2: the AOT-compiled Pallas/JAX artifacts computing MinHash
//!    band hashes, executed from rust via PJRT (`--backend xla` path).
//! 2. Layer 3: the streaming coordinator (parallel workers, bounded
//!    channels, sequential Bloom-index stage).
//! 3. The MinHashLSH baseline on the identical stream — reproducing the
//!    paper's headline comparison (throughput ratio + index size ratio)
//!    at local scale, plus fidelity vs ground-truth labels.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::stream::StreamSpec;
use lshbloom::eval::Confusion;
use lshbloom::methods::{MethodKind, MethodSpec};
use lshbloom::minhash::PermFamily;
use lshbloom::pipeline::{run_stream, PipelineOptions, RunStats};
use lshbloom::report::table::{bytes, f, Table};

fn main() {
    let docs: u64 = std::env::var("LSHBLOOM_E2E_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let spec = StreamSpec::pes2o_sim(0xE2E, docs);
    println!("e2e: {} docs of peS2o-sim (dup rate {})", docs, spec.dup_rate);

    let labels: Vec<bool> = spec.stream().map(|ld| ld.is_duplicate()).collect();
    let sample: Vec<lshbloom::corpus::Doc> =
        spec.stream().take(500).map(|ld| ld.doc).collect();

    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 256,
        p_effective: 1e-10,
        expected_docs: docs,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    };

    let mut rows: Vec<(String, RunStats)> = Vec::new();

    // --- LSHBloom, XLA backend (full three-layer stack). ---
    match lshbloom::runtime::lshbloom_method_xla(&cfg) {
        Ok(mut xla) => {
            let stats = run_stream(
                &mut xla,
                spec.stream().map(|ld| ld.doc),
                PipelineOptions::from_config(&cfg),
            );
            rows.push(("lshbloom (xla artifacts)".into(), stats));
        }
        Err(e) => {
            eprintln!("xla backend unavailable ({e}); run `make artifacts` — continuing");
        }
    }

    // --- LSHBloom, native backend. ---
    let mut native =
        lshbloom::methods::lshbloom::lshbloom_method(&cfg, PermFamily::Mix64);
    let stats = run_stream(
        &mut native,
        spec.stream().map(|ld| ld.doc),
        PipelineOptions::from_config(&cfg),
    );
    rows.push(("lshbloom (native)".into(), stats));

    // --- MinHashLSH baseline. ---
    let mut baseline = MethodSpec::best(MethodKind::MinHashLsh, docs).build(&sample);
    let stats = run_stream(
        &mut baseline,
        spec.stream().map(|ld| ld.doc),
        PipelineOptions::from_config(&cfg),
    );
    rows.push(("minhashlsh (baseline)".into(), stats));

    // --- Report. ---
    let mut t = Table::new(
        "end-to-end results",
        &["system", "docs/s", "wall (s)", "index disk", "dups found", "precision", "recall", "F1"],
    );
    for (name, stats) in &rows {
        let c = Confusion::from_verdicts(&stats.verdicts, &labels);
        t.row_disp(&[
            name.clone(),
            format!("{:.0}", stats.throughput()),
            f(stats.times.wall.as_secs_f64(), 1),
            bytes(stats.disk_bytes),
            stats.duplicates.to_string(),
            f(c.precision(), 4),
            f(c.recall(), 4),
            f(c.f1(), 4),
        ]);
    }
    t.print();

    // Headline ratios (paper: 12x throughput, 18x disk on peS2o).
    let native_stats = &rows.iter().find(|(n, _)| n.contains("native")).unwrap().1;
    let base_stats = &rows.iter().find(|(n, _)| n.contains("baseline")).unwrap().1;
    let speedup = base_stats.times.wall.as_secs_f64() / native_stats.times.wall.as_secs_f64();
    let disk_adv = base_stats.disk_bytes as f64 / native_stats.disk_bytes as f64;
    println!("\nheadline: LSHBloom vs MinHashLSH — {speedup:.1}x wall-clock, {disk_adv:.1}x disk");

    // Verdict agreement between XLA and native paths must be exact.
    if rows.len() == 3 {
        assert_eq!(
            rows[0].1.verdicts, rows[1].1.verdicts,
            "XLA and native verdicts must be identical"
        );
        println!("xla/native verdict agreement: exact ({} docs)", docs);
    }
    println!("ok");
}
