//! Quickstart: 60 seconds with the LSHBloom public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a small labeled corpus, deduplicates it with LSHBloom through
//! the parallel pipeline, and prints fidelity + resource numbers.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{DatasetSpec, LabeledCorpus};
use lshbloom::eval::Confusion;
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::minhash::PermFamily;
use lshbloom::pipeline::{run_stream, PipelineOptions};
use lshbloom::report::table::{bytes, f, Table};

fn main() {
    // 1. A corpus with ground-truth duplicate labels: 5k docs, 40%
    //    near-duplicates (parser noise + truncations, §5.1.4 style).
    let corpus = LabeledCorpus::build(DatasetSpec::testing(2024, 5_000, 0.4));
    println!(
        "corpus: {} docs, {} labeled duplicates",
        corpus.docs.len(),
        corpus.num_duplicates()
    );

    // 2. Configure LSHBloom: Jaccard threshold 0.5, 256 permutations
    //    (Table 1 best settings), index-wide false-positive bound 1e-10.
    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 256,
        p_effective: 1e-10,
        expected_docs: corpus.docs.len() as u64,
        ..Default::default()
    };
    let mut method = lshbloom_method(&cfg, PermFamily::Mix64);

    // 3. Run the streaming pipeline (parallel MinHash workers, sequential
    //    Bloom index stage).
    let stats = run_stream(
        &mut method,
        corpus.docs.iter().map(|ld| ld.doc.clone()),
        PipelineOptions::default(),
    );

    // 4. Score against the labels.
    let labels: Vec<bool> = corpus.docs.iter().map(|ld| ld.is_duplicate()).collect();
    let c = Confusion::from_verdicts(&stats.verdicts, &labels);

    let mut t = Table::new("LSHBloom quickstart", &["metric", "value"]);
    t.row_disp(&["documents".to_string(), stats.docs.to_string()]);
    t.row_disp(&["flagged duplicates".to_string(), stats.duplicates.to_string()]);
    t.row_disp(&["precision".to_string(), f(c.precision(), 4)]);
    t.row_disp(&["recall".to_string(), f(c.recall(), 4)]);
    t.row_disp(&["F1".to_string(), f(c.f1(), 4)]);
    t.row_disp(&["throughput".to_string(), format!("{:.0} docs/s", stats.throughput())]);
    t.row_disp(&["index size".to_string(), bytes(stats.disk_bytes)]);
    t.print();

    assert!(c.f1() > 0.8, "quickstart should achieve strong F1");
    println!("ok");
}
